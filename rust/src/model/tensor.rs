//! f32 tensor entry points for the L3 hot path — thin wrappers over the
//! [`crate::kernel`] substrate.
//!
//! The only dense math Rust does per training step is O(m·r) optimizer
//! updates; the O(m·n·r) lift runs once per K steps (Algorithm 1 line
//! 8). Since the kernel refactor this module contains **no GEMM loops
//! of its own**: both entry points delegate to the shared
//! Scalar-generic `gemm_nt` kernel (the same code the f64 `linalg`
//! stack uses), which runs on the global kernel pool, rides the
//! [`crate::kernel::simd`] vector core, and is bitwise identical at
//! every thread count and under either SIMD backend (fixed-lane
//! accumulation order).

use crate::kernel;

/// C += A·Bᵀ with A (m×r), B (n×r), C (m×n), all row-major f32.
/// This is exactly the lift ΔΘ = B_aux·Vᵀ with A = B_aux, B = V.
pub fn gemm_nt_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, r: usize) {
    kernel::auto::gemm_nt(1.0f32, a, b, c, m, n, r);
}

/// Θ += B_aux·Vᵀ — the Algorithm 1 outer update, in place.
pub fn lift_into(theta: &mut [f32], b_aux: &[f32], v: &[f32], m: usize, n: usize, r: usize) {
    gemm_nt_f32(b_aux, v, theta, m, n, r);
}

/// Θ += scale·Z·Vᵀ — the ZO/LR update direction lifted to the full
/// space (used by the Vanilla-LR trainer where the estimator is
/// scale·Z·Vᵀ with scale = (F⁺−F⁻)/(2σ)). The scaling is fused into the
/// kernel's α so the rank-r product is formed exactly once.
pub fn zo_update_into(
    theta: &mut [f32],
    z: &[f32],
    v: &[f32],
    scale: f32,
    m: usize,
    n: usize,
    r: usize,
) {
    kernel::auto::gemm_nt(scale, z, v, theta, m, n, r);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_nt_matches_naive() {
        let (m, n, r) = (5, 7, 3);
        let a: Vec<f32> = (0..m * r).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let b: Vec<f32> = (0..n * r).map(|i| (i as f32) * 0.05 - 0.3).collect();
        let mut c = vec![1.0f32; m * n];
        gemm_nt_f32(&a, &b, &mut c, m, n, r);
        for i in 0..m {
            for j in 0..n {
                let mut want = 1.0;
                for k in 0..r {
                    want += a[i * r + k] * b[j * r + k];
                }
                assert!((c[i * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn lift_matches_rank1_outer_product() {
        // r = 1: Θ += b·vᵀ
        let (m, n) = (3, 4);
        let b = vec![1.0f32, 2.0, 3.0];
        let v = vec![0.5f32, -1.0, 0.0, 2.0];
        let mut theta = vec![0.0f32; m * n];
        lift_into(&mut theta, &b, &v, m, n, 1);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(theta[i * n + j], b[i] * v[j]);
            }
        }
    }

    #[test]
    fn zo_update_scales() {
        let (m, n, r) = (2, 2, 2);
        let z = vec![1.0f32, 0.0, 0.0, 1.0];
        let v = vec![1.0f32, 0.0, 0.0, 1.0];
        let mut theta = vec![0.0f32; 4];
        zo_update_into(&mut theta, &z, &v, -2.0, m, n, r);
        assert_eq!(theta, vec![-2.0, 0.0, 0.0, -2.0]); // −2·I
    }

    #[test]
    fn lift_consistent_with_f64_linalg() {
        use crate::linalg::{matmul_nt, Mat};
        let (m, n, r) = (9, 11, 4);
        let mut rng = crate::rng::Rng::new(5);
        let a64 = Mat::from_fn(m, r, |_, _| rng.normal());
        let b64 = Mat::from_fn(n, r, |_, _| rng.normal());
        let want = matmul_nt(&a64, &b64);
        let a32: Vec<f32> = a64.data.iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b64.data.iter().map(|&x| x as f32).collect();
        let mut c = vec![0.0f32; m * n];
        lift_into(&mut c, &a32, &b32, m, n, r);
        for (got, want) in c.iter().zip(&want.data) {
            assert!((*got as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn zo_update_propagates_nan_from_v() {
        // branchless kernel: a NaN in V must reach Θ even when Z is zero
        let (m, n, r) = (2, 2, 1);
        let z = vec![0.0f32, 1.0];
        let v = vec![f32::NAN, 1.0];
        let mut theta = vec![0.0f32; m * n];
        zo_update_into(&mut theta, &z, &v, 1.0, m, n, r);
        assert!(theta[0].is_nan()); // 0·NaN
        assert!(theta[2].is_nan()); // 1·NaN
        assert!(!theta[3].is_nan());
    }
}
