//! Data-parallel coordination (the paper's "Distributed Data Parallel
//! for multi-GPU acceleration", DESIGN.md §2) — both topologies:
//!
//! * **In-process**: N producer threads on one trainer, each owning an
//!   independent RNG stream and feeding batch shards through its own
//!   bounded channel; the trainer pulls one shard per worker per step
//!   **in worker order** (deterministic — shard order is a pure
//!   function of the worker index, never of thread timing), executes
//!   the grad artifact per shard, and all-reduces on the kernel pool.
//! * **Multi-process**: each rank of a `lowrank-sge launch` tree owns a
//!   contiguous slice of the global worker set
//!   ([`BatchProducer::spawn_lm_slice`] keeps the per-worker RNG
//!   streams identical to the single-process run), reduces its local
//!   shards with the same pairing tree, and folds the partial sums
//!   across ranks through [`crate::comm`]'s ring/tree collectives.
//!
//! The [`Collective`] enum is the backend switch: `InProcess` is the
//! classic single-process path, `Comm` wraps a
//! [`crate::comm::Communicator`] built from the `launch` env. Because
//! the cross-process combine order matches the in-process pairing tree
//! (see [`crate::comm::collective`]), a `launch --nproc W` run with one
//! worker per rank is bitwise identical to the single-process W-worker
//! run — the property `tests/launch_ddp.rs` pins down.
//!
//! # Leader discipline (enforced)
//!
//! Exactly one rank — [`LEADER_RANK`] — may write shared side effects
//! (checkpoints, LATEST updates, metrics files). This is no longer just
//! a comment: the pretrain save point runs `is_leader()` → `save_state`
//! (which itself bails via [`Collective::assert_leader`] if a
//! non-leader rank ever reaches it) → `barrier()`, and `main` gates
//! metrics/export writes the same way. [`Collective::leader_writes`]
//! packages that gate-write-barrier sequence for closure-friendly call
//! sites (the world=2 regression test drives it). Non-leader ranks skip
//! the write but still cross the same barrier, so every rank leaves the
//! save point with the same step count. Durability timing
//! depends on the write path: a synchronous closure is committed when
//! the barrier releases; the pretrain trainer's asynchronous saves
//! ([`crate::ckpt::AsyncCheckpointer`]) commit in the background and
//! only guarantee the `LATEST` state is on disk once the writer drains
//! (at the next save, or at end of run).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::comm::{Algorithm, Communicator, RingPending};
use crate::data::{LmBatcher, ZipfMarkovCorpus};
use crate::kernel::KernelPool;
use crate::rng::Rng;

/// Rank that owns shared side effects (checkpoint writes, LATEST
/// updates, metrics files). Enforced at runtime by
/// [`Collective::leader_writes`] and the trainers' `save_state` guard:
/// every rank reaches the save barrier, exactly one writes.
pub const LEADER_RANK: usize = 0;

/// A batch shard produced by one worker. `worker` is the *global*
/// worker index (stable across in-process and multi-process runs).
#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub tokens: Vec<i32>,
}

/// Handle to the worker pool. One bounded channel per worker: the
/// trainer drains them in worker order, so the shard sequence a step
/// sees is deterministic (and a resumed run rejoins every stream
/// exactly, at any worker count).
pub struct BatchProducer {
    rxs: Vec<mpsc::Receiver<Shard>>,
    handles: Vec<JoinHandle<()>>,
}

impl BatchProducer {
    /// Spawn all `workers` producer threads (the single-process
    /// topology): worker w generates `(batch, seq+1)` LM shards from
    /// the stream `seed_rng.fork(w+1)`. `depth` bounds the *total*
    /// queued shards (split evenly across the per-worker channels —
    /// the backpressure a real input pipeline has). `skip`
    /// fast-forwards every worker past its first `skip` batches, so a
    /// `--resume` at step S rejoins each stream exactly where the
    /// interrupted run left off — per-worker channels make this exact
    /// at any worker count.
    pub fn spawn_lm(
        corpus: ZipfMarkovCorpus,
        batch: usize,
        seq_len: usize,
        workers: usize,
        depth: usize,
        seed_rng: &mut Rng,
        skip: u64,
    ) -> Self {
        let per_worker = (depth.max(workers) / workers.max(1)).max(1);
        Self::spawn_lm_slice(
            corpus, batch, seq_len, workers, 0, workers, per_worker, seed_rng, skip,
        )
    }

    /// Spawn the worker slice `[first, first + count)` out of a global
    /// set of `total_workers` (the multi-process topology: rank r of
    /// world W owns `count = total/W` workers starting at `r·count`).
    ///
    /// Every worker stream in the *global* set is forked from
    /// `seed_rng` in index order — including the workers this rank does
    /// not own — so worker w's stream is identical no matter which rank
    /// runs it (and `seed_rng` itself advances identically on every
    /// rank). `depth` here is the per-worker queue bound.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_lm_slice(
        corpus: ZipfMarkovCorpus,
        batch: usize,
        seq_len: usize,
        total_workers: usize,
        first: usize,
        count: usize,
        depth: usize,
        seed_rng: &mut Rng,
        skip: u64,
    ) -> Self {
        assert!(count >= 1, "a producer needs at least one worker");
        assert!(
            first + count <= total_workers,
            "worker slice [{first}, {}) exceeds the global worker set of {total_workers}",
            first + count
        );
        let mut rxs = Vec::with_capacity(count);
        let mut handles = Vec::with_capacity(count);
        for w in 0..total_workers {
            let rng = seed_rng.fork(w as u64 + 1);
            if w < first || w >= first + count {
                continue; // another rank's worker; stream consumed for parity
            }
            let (tx, rx) = mpsc::sync_channel::<Shard>(depth.max(1));
            let corpus = corpus.clone();
            handles.push(std::thread::spawn(move || {
                let mut batcher = LmBatcher::new(corpus, batch, seq_len, rng);
                for _ in 0..skip {
                    let _ = batcher.next_batch();
                }
                loop {
                    let tokens = batcher.next_batch();
                    if tx.send(Shard { worker: w, tokens }).is_err() {
                        return; // trainer dropped the receiver: shut down
                    }
                }
            }));
            rxs.push(rx);
        }
        BatchProducer { rxs, handles }
    }

    /// Number of local workers (the slice this producer owns).
    pub fn workers(&self) -> usize {
        self.rxs.len()
    }

    /// Pull one shard per local worker, in worker order — a full local
    /// step's worth, in a deterministic sequence.
    pub fn next_step_shards(&self) -> Vec<Shard> {
        self.rxs
            .iter()
            .map(|rx| rx.recv().expect("producer thread died"))
            .collect()
    }

    /// Shut the pool down (drop the receivers, join the threads).
    pub fn shutdown(self) {
        drop(self.rxs);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// All-reduce (mean) a set of per-worker gradient vectors in place into
/// the first one, on the global kernel pool. Returns the number of
/// shards reduced. See [`allreduce_mean_with`] for the reduction order
/// and the scratch-use of `grads[1..]`.
pub fn allreduce_mean(grads: &mut [Vec<f32>]) -> usize {
    allreduce_mean_with(&crate::kernel::global(), grads)
}

/// All-reduce (mean) with an explicit pool.
///
/// Shards combine in a **fixed pairing order** — the stride-doubling
/// binary tree of [`crate::kernel::tree_sum_vecs`] (`g[i] += g[i+gap]`
/// for gap = 1, 2, 4, …) — and each pairwise add is chunked elementwise
/// across the pool. Both the tree shape (a function of the worker count
/// alone) and the chunking (disjoint elements) are independent of the
/// thread count, so the reduced gradient is bitwise identical from 1
/// thread to N — and the `comm` collectives reuse the same order across
/// processes.
///
/// Only `grads[0]` holds the result; the tree uses the remaining
/// shards as scratch (inner nodes hold partial sums afterwards), so
/// callers must not read `grads[1..]` after the reduce.
pub fn allreduce_mean_with(pool: &crate::kernel::KernelPool, grads: &mut [Vec<f32>]) -> usize {
    let n = grads.len();
    assert!(n >= 1);
    let len = grads[0].len();
    for g in grads.iter() {
        assert_eq!(g.len(), len, "gradient length mismatch across workers");
    }
    crate::kernel::tree_sum_vecs(pool, grads);
    let inv = 1.0 / n as f32;
    crate::kernel::scale(pool, &mut grads[0], inv);
    n
}

/// The gradient-averaging backend a trainer runs on.
///
/// `InProcess` is the classic topology: every worker shard lives on
/// this trainer, one pairing-tree reduce finishes the job. `Comm` is a
/// rank in a `launch` world: the local shards tree-reduce first, the
/// per-rank partials fold across processes with the same pairing tree
/// (ring or tree transport — bitwise identical either way), and the
/// mean is taken over the *global* shard count.
///
/// When the per-rank shard count is a power of two (it is 1 in the
/// canonical one-worker-per-rank deployment), the local-then-cross
/// association is exactly the global pairing tree, so distributed
/// results are bitwise identical to the single-process run.
pub enum Collective {
    InProcess,
    Comm(Communicator),
}

impl Collective {
    pub fn in_process() -> Self {
        Collective::InProcess
    }

    /// Build from the `launch` env: `Comm` inside a launch tree,
    /// `InProcess` otherwise.
    pub fn from_env() -> Result<Self> {
        Self::from_env_with_dtype(None)
    }

    /// [`Self::from_env`] with the subcommand's own `--comm-dtype`
    /// override applied **before** connect, so the dtype handshake
    /// guards the lane the trainer will actually use. Every rank of a
    /// launch world parses the identical argv, so the override is
    /// SPMD-consistent by construction.
    pub fn from_env_with_dtype(dtype_override: Option<crate::comm::WireDtype>) -> Result<Self> {
        Ok(match Communicator::from_env_with(dtype_override)? {
            Some(comm) => Collective::Comm(comm),
            None => Collective::InProcess,
        })
    }

    pub fn rank(&self) -> usize {
        match self {
            Collective::InProcess => LEADER_RANK,
            Collective::Comm(c) => c.rank(),
        }
    }

    pub fn world(&self) -> usize {
        match self {
            Collective::InProcess => 1,
            Collective::Comm(c) => c.world(),
        }
    }

    pub fn is_leader(&self) -> bool {
        self.rank() == LEADER_RANK
    }

    pub fn is_distributed(&self) -> bool {
        matches!(self, Collective::Comm(_))
    }

    /// All-reduce (mean) the per-worker gradients of one step: local
    /// pairing-tree sum, cross-rank fold for `Comm`, one scale by the
    /// global shard count. `grads[0]` holds the result (the rest are
    /// tree scratch); returns the global shard count.
    pub fn allreduce_mean_shards(&mut self, grads: &mut [Vec<f32>]) -> Result<usize> {
        let n_local = grads.len();
        assert!(n_local >= 1);
        let pool = crate::kernel::global();
        crate::kernel::tree_sum_vecs(&pool, grads);
        let total = match self {
            Collective::InProcess => n_local,
            Collective::Comm(c) => {
                c.allreduce_sum(&mut grads[0])?;
                n_local * c.world()
            }
        };
        crate::kernel::scale(&pool, &mut grads[0], 1.0 / total as f32);
        Ok(total)
    }

    /// All-reduce (mean) a whole step's worth of gradient slots in one
    /// pipelined pass — `slots[k]` holds slot k's per-local-worker
    /// shard vectors, exactly as [`Self::allreduce_mean_shards`] takes
    /// them, and afterwards `slots[k][0]` holds slot k's global mean
    /// (the rest are tree scratch). Returns the global shard count.
    ///
    /// Arithmetic is identical to calling `allreduce_mean_shards` on
    /// each slot in order — bitwise, in both wire dtypes — but on the
    /// `Comm` backend the *schedule* overlaps: while slot k's ring
    /// exchange is on the sockets, the helper thread is already running
    /// slot k+1's local shard reduce on the kernel pool, and slot k's
    /// post-exchange chunk reduce follows on the same thread while the
    /// next exchange starts — with at most [`PIPELINE_WINDOW`] ring
    /// collectives in flight. The socket schedule is a pure function of
    /// (world, slot lengths, algorithm) — never of pool or arrival
    /// timing — so every rank interleaves identically and determinism
    /// is untouched.
    pub fn allreduce_mean_slots(&mut self, slots: &mut [Vec<Vec<f32>>]) -> Result<usize> {
        let Some(first) = slots.first() else { return Ok(0) };
        let n_local = first.len();
        assert!(n_local >= 1, "each slot needs at least one local shard");
        for g in slots.iter() {
            assert_eq!(g.len(), n_local, "local shard count mismatch across slots");
        }
        let pool = crate::kernel::global();
        match self {
            Collective::InProcess => {
                reduce_slots_local(&pool, slots, n_local);
                Ok(n_local)
            }
            Collective::Comm(c) if c.world() == 1 => {
                // a 1-rank world is the in-process run, bitwise
                reduce_slots_local(&pool, slots, n_local);
                Ok(n_local)
            }
            Collective::Comm(c) => {
                let total = n_local * c.world();
                pipeline_ring_slots(c, &pool, slots, 1.0 / total as f32)?;
                Ok(total)
            }
        }
    }

    /// Mean of a per-shard scalar sum (the step loss): `local_sum` is
    /// this rank's plain sequential sum over its `local_n` shards, the
    /// cross-rank fold uses the pairing tree, the division is by the
    /// global shard count. With one shard per rank this matches the
    /// in-process arithmetic bitwise; with several local shards the
    /// association is local-sums-then-rank-tree, which agrees with the
    /// in-process sequential sum only in value, not necessarily in
    /// bits (same power-of-two caveat as the enum docs — the *gradient*
    /// path is what the bitwise checkpoint contract covers). The scalar
    /// is control-path traffic and always rides the f32 lane: rounding
    /// a logged loss to bf16 would cost metric precision for a saving
    /// of two bytes.
    pub fn allreduce_mean_scalar(&mut self, local_sum: f32, local_n: usize) -> Result<f32> {
        assert!(local_n >= 1);
        match self {
            Collective::InProcess => Ok(local_sum / local_n as f32),
            Collective::Comm(c) => {
                let mut v = [local_sum];
                c.allreduce_sum_f32_lane(&mut v)?;
                Ok(v[0] / (local_n * c.world()) as f32)
            }
        }
    }

    /// Block until every rank reached this point (no-op in-process).
    /// Stamps the monitor's Barrier watermark before blocking, so a
    /// stall watchdog can tell "waiting at a barrier" (watermark fresh,
    /// phase = barrier) from "wedged mid-step" (no watermark advance).
    pub fn barrier(&mut self) -> Result<()> {
        crate::obs::monitor::stamp(crate::obs::monitor::Phase::Barrier, 0);
        match self {
            Collective::InProcess => Ok(()),
            Collective::Comm(c) => c.barrier(),
        }
    }

    /// Gather every rank's equal-length contribution into
    /// `out[rank·len .. (rank+1)·len]` on all ranks. In-process the
    /// world is 1, so `out` must equal `mine` in length and receives a
    /// plain copy — the degenerate gather. (The obs layer rides this to
    /// pull every rank's metrics snapshot to the leader.)
    pub fn all_gather(&mut self, mine: &[f32], out: &mut [f32]) -> Result<()> {
        match self {
            Collective::InProcess => {
                if out.len() != mine.len() {
                    bail!(
                        "all_gather output has {} elements, expected {} at world 1",
                        out.len(),
                        mine.len()
                    );
                }
                out.copy_from_slice(mine);
                Ok(())
            }
            Collective::Comm(c) => c.all_gather(mine, out),
        }
    }

    /// The enforced [`LEADER_RANK`] discipline for shared side effects:
    /// run `write` only on the leader, then barrier so every rank
    /// leaves the save point together. When `write` performs the side
    /// effect synchronously, non-leaders observe the committed state
    /// once the barrier releases them; a `write` that merely *queues*
    /// an async save (the pretrain trainer's path) defers that
    /// guarantee to the writer's drain point.
    pub fn leader_writes<F: FnOnce() -> Result<()>>(&mut self, write: F) -> Result<()> {
        if self.is_leader() {
            write()?;
        }
        self.barrier()
    }

    /// Guard for write paths that must never run off-leader.
    pub fn assert_leader(&self, what: &str) -> Result<()> {
        if !self.is_leader() {
            bail!(
                "{what} is restricted to the DDP leader (rank {LEADER_RANK}); \
                 this is rank {} of {}",
                self.rank(),
                self.world()
            );
        }
        Ok(())
    }
}

/// End-of-run observability export, called by both trainers (and
/// `comm-check`) after their last collective:
///
/// 1. **Metrics** (`--metrics-out`): every rank serializes its registry
///    snapshot to a fixed-size f32 frame
///    ([`crate::obs::metrics::encode_snapshot`]) and the frames ride the
///    existing `all_gather`; the leader decodes all `world` JSON lines,
///    writes the merged JSONL, and prints the per-rank summary table.
/// 2. **Trace** (`--trace-out`): every rank drains its span rings into
///    its rank-scoped Chrome-trace file, a barrier ensures all files
///    are on the (shared — `launch` is single-host) filesystem, then
///    the leader string-merges them into the requested path.
///
/// A no-op when neither output was requested. SPMD: every rank must
/// call this (the gather and barrier are collectives).
pub fn export_run_obs(collective: &mut Collective) -> Result<()> {
    use crate::obs;
    let (rank, world) = (collective.rank(), collective.world());
    if let Some(path) = obs::metrics_out() {
        let frame = obs::metrics::encode_snapshot(&obs::metrics::snapshot_json(rank));
        let mut gathered = vec![0.0f32; frame.len() * world];
        collective.all_gather(&frame, &mut gathered)?;
        if collective.is_leader() {
            let lines = (0..world)
                .map(|r| {
                    obs::metrics::decode_snapshot(&gathered[r * frame.len()..(r + 1) * frame.len()])
                })
                .collect::<Result<Vec<String>>>()?;
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            use std::io::Write;
            let mut f = std::fs::File::create(&path)?;
            for line in &lines {
                writeln!(f, "{line}")?;
            }
            println!("{}", crate::obs::metrics::summary_table(&lines));
            println!("metrics JSONL ({} ranks) written to {}", world, path.display());
        }
    }
    if obs::export_rank_trace(rank, world)?.is_some() {
        // all rank files must be durable before the leader merges
        collective.barrier()?;
        if collective.is_leader() {
            if let Some(merged) = obs::merge_rank_traces(world)? {
                println!("chrome trace written to {}", merged.display());
            }
        }
    }
    Ok(())
}

/// Upper bound on ring collectives in flight inside
/// [`Collective::allreduce_mean_slots`]: slot k's chunk reduce may
/// still be running on the kernel pool while slot k+1's ring exchange
/// is on the wire. Two is enough to hide the reduce latency (the
/// schedule strictly alternates exchange/gather after warm-up) without
/// holding more than one extra slot's chunk copies in memory.
pub const PIPELINE_WINDOW: usize = 2;

/// Serial local reduction: one pairing-tree sum + mean scale per slot
/// (the in-process backend of [`Collective::allreduce_mean_slots`]).
fn reduce_slots_local(pool: &KernelPool, slots: &mut [Vec<Vec<f32>>], n_local: usize) {
    let inv = 1.0 / n_local as f32;
    for g in slots.iter_mut() {
        crate::kernel::tree_sum_vecs(pool, g);
        crate::kernel::scale(pool, &mut g[0], inv);
    }
}

/// One unit of pool work shipped to the pipeline's helper thread:
/// either a slot's local per-worker shard reduce (the pairing tree) or
/// its post-exchange chunk reduce. One FIFO helper runs both, so
/// completions come back strictly in submission order — the property
/// the main loop's recv discipline is built on.
enum SlotJob {
    /// Local pairing-tree reduce of slot k's per-worker shards.
    Shards(usize, Vec<Vec<f32>>),
    /// Post-exchange chunk reduce of slot k's in-flight ring collective.
    Chunks(usize, RingPending),
}

/// Complete the oldest in-flight ring collective: take its reduced
/// chunks from the helper thread (jobs complete in submission order, so
/// the next done item must be this slot's chunk reduce), gather, and
/// scale to the global mean.
fn finish_oldest(
    c: &mut Communicator,
    pool: &KernelPool,
    slots: &mut [Vec<Vec<f32>>],
    inv: f32,
    in_flight: &mut VecDeque<usize>,
    done_rx: &mpsc::Receiver<SlotJob>,
) -> Result<()> {
    let j = in_flight.pop_front().expect("finish_oldest on an empty window");
    let pending = match done_rx.recv().expect("slot reducer thread died") {
        SlotJob::Chunks(k, pending) => {
            debug_assert_eq!(k, j, "reducer completed slots out of order");
            pending
        }
        SlotJob::Shards(k, _) => panic!("shard reduce of slot {k} completed out of schedule"),
    };
    c.ring_gather(pending, &mut slots[j][0])?;
    crate::kernel::scale(pool, &mut slots[j][0], inv);
    Ok(())
}

/// The slot-pipelined cross-rank schedule behind
/// [`Collective::allreduce_mean_slots`]. Per slot: local shard reduce
/// (pairing tree, on the helper thread, overlapped with the *previous*
/// slot's ring exchange) → ring exchange (sockets) → chunk reduce
/// (helper thread again, overlapped with the *next* slot's exchange) →
/// ring gather (sockets) → scale. Slots the algorithm routes to the
/// tree transport drain the window first and run whole, so the frame
/// schedule every peer sees is the same pure function of (world, slot
/// lengths, algorithm) on every rank.
///
/// Both job kinds ride one FIFO helper, so the done stream interleaves
/// deterministically (S0 | S1, C0 | S2, C1 | …): iteration k's first
/// recv is always its own shard reduce, and every `finish_oldest` recv
/// is the oldest outstanding chunk reduce. `tree_sum_vecs` is
/// bitwise-identical at any pool size and from any calling thread, so
/// moving the shard reduce off-thread changes timing only.
fn pipeline_ring_slots(
    c: &mut Communicator,
    pool: &Arc<KernelPool>,
    slots: &mut [Vec<Vec<f32>>],
    inv: f32,
) -> Result<()> {
    if slots.is_empty() {
        return Ok(());
    }
    let algo = c.algorithm();
    std::thread::scope(|scope| -> Result<()> {
        let (job_tx, job_rx) = mpsc::channel::<SlotJob>();
        let (done_tx, done_rx) = mpsc::channel::<SlotJob>();
        let reduce_pool = Arc::clone(pool);
        // pool work runs here so the caller can keep the sockets busy
        scope.spawn(move || {
            for job in job_rx {
                let done = match job {
                    SlotJob::Shards(k, mut shards) => {
                        crate::kernel::tree_sum_vecs(&reduce_pool, &mut shards);
                        SlotJob::Shards(k, shards)
                    }
                    SlotJob::Chunks(k, mut pending) => {
                        pending.reduce(&reduce_pool);
                        SlotJob::Chunks(k, pending)
                    }
                };
                if done_tx.send(done).is_err() {
                    return; // caller bailed mid-pipeline
                }
            }
        });
        job_tx
            .send(SlotJob::Shards(0, std::mem::take(&mut slots[0])))
            .expect("slot reducer thread died");
        let mut in_flight: VecDeque<usize> = VecDeque::new();
        for k in 0..slots.len() {
            match done_rx.recv().expect("slot reducer thread died") {
                SlotJob::Shards(j, shards) => {
                    debug_assert_eq!(j, k, "shard reduces completed out of order");
                    slots[k] = shards;
                }
                SlotJob::Chunks(j, _) => {
                    panic!("chunk reduce of slot {j} completed before slot {k}'s shard reduce")
                }
            }
            if k + 1 < slots.len() {
                job_tx
                    .send(SlotJob::Shards(k + 1, std::mem::take(&mut slots[k + 1])))
                    .expect("slot reducer thread died");
            }
            // one routing predicate, shared with the serial
            // `allreduce_sum_with` — serial ≡ pipelined depends on it
            if algo.routes_to_ring(slots[k][0].len()) {
                let pending = c.ring_exchange(&mut slots[k][0])?;
                job_tx.send(SlotJob::Chunks(k, pending)).expect("slot reducer thread died");
                in_flight.push_back(k);
                if in_flight.len() >= PIPELINE_WINDOW {
                    finish_oldest(c, pool, slots, inv, &mut in_flight, &done_rx)?;
                }
            } else {
                while !in_flight.is_empty() {
                    finish_oldest(c, pool, slots, inv, &mut in_flight, &done_rx)?;
                }
                c.allreduce_sum_with(Algorithm::Tree, &mut slots[k][0])?;
                crate::kernel::scale(pool, &mut slots[k][0], inv);
            }
        }
        drop(job_tx);
        while !in_flight.is_empty() {
            finish_oldest(c, pool, slots, inv, &mut in_flight, &done_rx)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_fast_forwards_the_stream_exactly() {
        let corpus = ZipfMarkovCorpus::new(64, 7);
        // reference: one worker, no skip, drain 5 batches
        let mut rng_a = Rng::new(9);
        let pool_a = BatchProducer::spawn_lm(corpus.clone(), 2, 4, 1, 2, &mut rng_a, 0);
        let batches: Vec<Vec<i32>> =
            (0..5).map(|_| pool_a.next_step_shards().remove(0).tokens).collect();
        pool_a.shutdown();
        // resumed: same seed, skip 3 → must continue at batch 3
        let mut rng_b = Rng::new(9);
        let pool_b = BatchProducer::spawn_lm(corpus, 2, 4, 1, 2, &mut rng_b, 3);
        assert_eq!(pool_b.next_step_shards().remove(0).tokens, batches[3]);
        assert_eq!(pool_b.next_step_shards().remove(0).tokens, batches[4]);
        pool_b.shutdown();
    }

    #[test]
    fn shards_have_right_shape_and_distinct_streams() {
        let corpus = ZipfMarkovCorpus::new(128, 3);
        let mut rng = Rng::new(1);
        let pool = BatchProducer::spawn_lm(corpus, 4, 8, 3, 8, &mut rng, 0);
        let shards = pool.next_step_shards();
        assert_eq!(shards.len(), 3);
        for s in &shards {
            assert_eq!(s.tokens.len(), 4 * 9);
        }
        // distinct worker streams ⇒ shards differ
        assert_ne!(shards[0].tokens, shards[1].tokens);
        pool.shutdown();
    }

    #[test]
    fn multi_worker_shard_order_is_deterministic() {
        let corpus = ZipfMarkovCorpus::new(64, 5);
        let drain = |seed: u64| -> Vec<Vec<i32>> {
            let mut rng = Rng::new(seed);
            let pool = BatchProducer::spawn_lm(corpus.clone(), 2, 4, 3, 6, &mut rng, 0);
            let mut out = Vec::new();
            for _ in 0..8 {
                for s in pool.next_step_shards() {
                    out.push(s.tokens);
                }
            }
            pool.shutdown();
            out
        };
        // identical runs see the identical shard sequence — per-worker
        // channels make multi-worker order timing-independent
        assert_eq!(drain(3), drain(3));
    }

    #[test]
    fn worker_slices_reproduce_the_full_set() {
        let corpus = ZipfMarkovCorpus::new(64, 11);
        // the single-process 2-worker reference
        let mut rng_full = Rng::new(5);
        let full = BatchProducer::spawn_lm(corpus.clone(), 2, 4, 2, 4, &mut rng_full, 0);
        let ref_shards = full.next_step_shards();
        full.shutdown();
        // two "ranks", one worker each, same seed
        let mut rng_r0 = Rng::new(5);
        let r0 = BatchProducer::spawn_lm_slice(corpus.clone(), 2, 4, 2, 0, 1, 2, &mut rng_r0, 0);
        let mut rng_r1 = Rng::new(5);
        let r1 = BatchProducer::spawn_lm_slice(corpus, 2, 4, 2, 1, 1, 2, &mut rng_r1, 0);
        let s0 = r0.next_step_shards().remove(0);
        let s1 = r1.next_step_shards().remove(0);
        assert_eq!(s0.worker, 0);
        assert_eq!(s1.worker, 1);
        assert_eq!(s0.tokens, ref_shards[0].tokens);
        assert_eq!(s1.tokens, ref_shards[1].tokens);
        // the parent stream advanced identically on both ranks
        assert_eq!(rng_r0.next_u64(), rng_r1.next_u64());
        r0.shutdown();
        r1.shutdown();
    }

    #[test]
    fn backpressure_queue_does_not_grow_unbounded() {
        let corpus = ZipfMarkovCorpus::new(64, 5);
        let mut rng = Rng::new(2);
        let pool = BatchProducer::spawn_lm(corpus, 2, 4, 2, 4, &mut rng, 0);
        // producers are rate-limited by the bounded channels: draining
        // several steps still works and terminates.
        for _ in 0..20 {
            let shards = pool.next_step_shards();
            assert_eq!(shards.len(), 2);
        }
        pool.shutdown();
    }

    #[test]
    fn allreduce_mean_averages() {
        let mut grads = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let n = allreduce_mean(&mut grads);
        assert_eq!(n, 3);
        assert_eq!(grads[0], vec![3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn allreduce_rejects_ragged() {
        let mut grads = vec![vec![1.0f32], vec![1.0, 2.0]];
        allreduce_mean(&mut grads);
    }

    #[test]
    fn in_process_collective_is_rank_zero_of_one() {
        let mut c = Collective::in_process();
        assert_eq!(c.rank(), LEADER_RANK);
        assert_eq!(c.world(), 1);
        assert!(c.is_leader());
        assert!(!c.is_distributed());
        let mut grads = vec![vec![2.0f32, 4.0], vec![4.0, 8.0]];
        assert_eq!(c.allreduce_mean_shards(&mut grads).unwrap(), 2);
        assert_eq!(grads[0], vec![3.0, 6.0]);
        assert_eq!(c.allreduce_mean_scalar(6.0, 2).unwrap(), 3.0);
        c.barrier().unwrap();
        let mut wrote = false;
        c.leader_writes(|| {
            wrote = true;
            Ok(())
        })
        .unwrap();
        assert!(wrote);
        assert!(c.assert_leader("test write").is_ok());
    }
}
