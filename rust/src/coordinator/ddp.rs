//! Data-parallel worker simulation (the paper's "Distributed Data
//! Parallel for multi-GPU acceleration", DESIGN.md §2).
//!
//! N producer threads each own an independent RNG stream and generate
//! batch shards into a bounded channel — the backpressure a real input
//! pipeline has. The leader (trainer) pulls one shard per worker per
//! step, executes the grad artifact per shard, and all-reduces (averages)
//! the gradients. PJRT execution stays on the leader thread: the CPU
//! plugin is single-device, so true parallel execute would only fight
//! over the one core; what is being exercised is the *coordination
//! topology* (sharding, channel backpressure, deterministic per-worker
//! streams, gradient all-reduce).

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::data::{LmBatcher, ZipfMarkovCorpus};
use crate::rng::Rng;

/// Rank that owns shared side effects (checkpoint writes, LATEST
/// updates, metrics files). In this in-process simulation the trainer
/// thread *is* rank 0 by construction, so the constant is documentation
/// of the contract rather than a runtime check; a real multi-process
/// DDP deployment must enforce the same discipline — every rank reaches
/// the step barrier, exactly one writes the checkpoint.
pub const LEADER_RANK: usize = 0;

/// A batch shard produced by one worker.
#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub tokens: Vec<i32>,
}

/// Handle to the worker pool.
pub struct BatchProducer {
    rx: mpsc::Receiver<Shard>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl BatchProducer {
    /// Spawn `workers` producer threads, each generating `(batch,
    /// seq+1)` LM shards from its own forked RNG stream. `depth` bounds
    /// the queue (backpressure). `skip` fast-forwards every worker past
    /// its first `skip` batches — on `--resume` at step S each stream is
    /// replayed to exactly where the interrupted run left off, so a
    /// single-worker resumed run sees the identical token sequence.
    /// (With several workers the rejoin is approximate: the interrupted
    /// run consumed `workers·S` shards in timing-dependent per-worker
    /// proportions and discarded up to `depth` queued shards, so exact
    /// per-stream positions are unknowable — matching the inherent
    /// nondeterminism of multi-worker shard ordering itself.)
    pub fn spawn_lm(
        corpus: ZipfMarkovCorpus,
        batch: usize,
        seq_len: usize,
        workers: usize,
        depth: usize,
        seed_rng: &mut Rng,
        skip: u64,
    ) -> Self {
        assert!(workers >= 1);
        let (tx, rx) = mpsc::sync_channel::<Shard>(depth.max(workers));
        let mut handles = Vec::new();
        for w in 0..workers {
            let tx = tx.clone();
            let corpus = corpus.clone();
            let rng = seed_rng.fork(w as u64 + 1);
            handles.push(std::thread::spawn(move || {
                let mut batcher = LmBatcher::new(corpus, batch, seq_len, rng);
                for _ in 0..skip {
                    let _ = batcher.next_batch();
                }
                loop {
                    let tokens = batcher.next_batch();
                    if tx.send(Shard { worker: w, tokens }).is_err() {
                        return; // trainer dropped the receiver: shut down
                    }
                }
            }));
        }
        BatchProducer { rx, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Pull one shard per worker (a full global step's worth).
    pub fn next_step_shards(&self) -> Vec<Shard> {
        (0..self.workers)
            .map(|_| self.rx.recv().expect("producer thread died"))
            .collect()
    }

    /// Shut the pool down (drop the receiver, join the threads).
    pub fn shutdown(self) {
        drop(self.rx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// All-reduce (mean) a set of per-worker gradient vectors in place into
/// the first one, on the global kernel pool. Returns the number of
/// shards reduced. See [`allreduce_mean_with`] for the reduction order
/// and the scratch-use of `grads[1..]`.
pub fn allreduce_mean(grads: &mut [Vec<f32>]) -> usize {
    allreduce_mean_with(&crate::kernel::global(), grads)
}

/// All-reduce (mean) with an explicit pool.
///
/// Shards combine in a **fixed pairing order** — a stride-doubling
/// binary tree over the worker index (`g[i] += g[i+gap]` for gap = 1,
/// 2, 4, …) — and each pairwise add is chunked elementwise across the
/// pool. Both the tree shape (a function of the worker count alone) and
/// the chunking (disjoint elements) are independent of the thread
/// count, so the reduced gradient is bitwise identical from 1 thread to
/// N — the property the DDP determinism tests pin down.
///
/// Only `grads[0]` holds the result; the tree uses the remaining
/// shards as scratch (inner nodes hold partial sums afterwards), so
/// callers must not read `grads[1..]` after the reduce.
pub fn allreduce_mean_with(pool: &crate::kernel::KernelPool, grads: &mut [Vec<f32>]) -> usize {
    let n = grads.len();
    assert!(n >= 1);
    let len = grads[0].len();
    for g in grads.iter() {
        assert_eq!(g.len(), len, "gradient length mismatch across workers");
    }
    let mut gap = 1;
    while gap < n {
        let mut i = 0;
        while i + gap < n {
            let (left, right) = grads.split_at_mut(i + gap);
            crate::kernel::add_assign(pool, &mut left[i], &right[0]);
            i += 2 * gap;
        }
        gap *= 2;
    }
    let inv = 1.0 / n as f32;
    crate::kernel::scale(pool, &mut grads[0], inv);
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_fast_forwards_the_stream_exactly() {
        let corpus = ZipfMarkovCorpus::new(64, 7);
        // reference: one worker, no skip, drain 5 batches
        let mut rng_a = Rng::new(9);
        let pool_a = BatchProducer::spawn_lm(corpus.clone(), 2, 4, 1, 2, &mut rng_a, 0);
        let batches: Vec<Vec<i32>> =
            (0..5).map(|_| pool_a.next_step_shards().remove(0).tokens).collect();
        pool_a.shutdown();
        // resumed: same seed, skip 3 → must continue at batch 3
        let mut rng_b = Rng::new(9);
        let pool_b = BatchProducer::spawn_lm(corpus, 2, 4, 1, 2, &mut rng_b, 3);
        assert_eq!(pool_b.next_step_shards().remove(0).tokens, batches[3]);
        assert_eq!(pool_b.next_step_shards().remove(0).tokens, batches[4]);
        pool_b.shutdown();
    }

    #[test]
    fn shards_have_right_shape_and_distinct_streams() {
        let corpus = ZipfMarkovCorpus::new(128, 3);
        let mut rng = Rng::new(1);
        let pool = BatchProducer::spawn_lm(corpus, 4, 8, 3, 8, &mut rng, 0);
        let shards = pool.next_step_shards();
        assert_eq!(shards.len(), 3);
        for s in &shards {
            assert_eq!(s.tokens.len(), 4 * 9);
        }
        // distinct worker streams ⇒ shards differ
        assert_ne!(shards[0].tokens, shards[1].tokens);
        pool.shutdown();
    }

    #[test]
    fn backpressure_queue_does_not_grow_unbounded() {
        let corpus = ZipfMarkovCorpus::new(64, 5);
        let mut rng = Rng::new(2);
        let pool = BatchProducer::spawn_lm(corpus, 2, 4, 2, 4, &mut rng, 0);
        // producers are rate-limited by the bounded channel: draining
        // several steps still works and terminates.
        for _ in 0..20 {
            let shards = pool.next_step_shards();
            assert_eq!(shards.len(), 2);
        }
        pool.shutdown();
    }

    #[test]
    fn allreduce_mean_averages() {
        let mut grads = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let n = allreduce_mean(&mut grads);
        assert_eq!(n, 3);
        assert_eq!(grads[0], vec![3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn allreduce_rejects_ragged() {
        let mut grads = vec![vec![1.0f32], vec![1.0, 2.0]];
        allreduce_mean(&mut grads);
    }
}
