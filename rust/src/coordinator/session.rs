//! Training sessions: the trainer-agnostic seam the serve daemon
//! schedules.
//!
//! A [`TrainSession`] is one training job reshaped from a blocking
//! `run()` call into an externally-driven state machine: construct →
//! [`TrainSession::step`] until it reports
//! [`SessionStatus::StepsExhausted`] → [`TrainSession::finish`]. Each
//! session owns its full per-job state — `GradEstimator` (B/V/Adam
//! moments), `AsyncCheckpointer` directory, RNG streams, task sampler —
//! so a scheduler may interleave `step()` calls across sessions in any
//! order without perturbing any one session's trajectory. The step and
//! epilogue bodies are the *same code* the standalone `finetune` /
//! `pretrain` subcommands execute (those subcommands are now thin
//! drivers over this seam), which is what pins the bitwise contract:
//! a single-job serve run produces byte-identical checkpoints to the
//! standalone subcommand at the same seed.

use std::path::Path;

use anyhow::{Context, Result};

use super::finetune::{FinetuneConfig, FinetuneLoop, FinetuneResult, FinetuneTrainer};
use super::pretrain::{PretrainConfig, PretrainLoop, PretrainResult, PretrainTrainer};
use crate::model::ParamStore;
use crate::runtime::Runtime;

/// Outcome of one scheduled step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    /// The session consumed one optimizer step; more remain.
    Running,
    /// Every step has run; call [`TrainSession::finish`] next.
    StepsExhausted,
}

/// What a finished session reports back (over the daemon's `status` /
/// `fetch` verbs, or to the standalone driver).
#[derive(Clone, Debug)]
pub struct SessionSummary {
    /// `"finetune"` or `"pretrain"`.
    pub kind: &'static str,
    /// Final eval metric: accuracy (finetune) or eval loss (pretrain).
    pub metric: Option<f64>,
    /// Mean training loss over the last 10 recorded steps.
    pub tail_loss: Option<f32>,
    /// Step cursor at finish (== configured steps unless zero-shot).
    pub steps_done: u64,
}

/// One schedulable training job. Implementations must keep `step()`
/// re-entrant with respect to *other* sessions: no hidden global
/// mutable state, so round-robin interleaving is safe and
/// deterministic per session.
pub trait TrainSession {
    /// Run exactly one optimizer step (or report exhaustion).
    fn step(&mut self) -> Result<SessionStatus>;

    /// `(next step index, total configured steps)`.
    fn progress(&self) -> (u64, u64);

    /// Non-blocking background-IO probe: surfaces an async checkpoint
    /// write error as soon as the writer thread has finished, without
    /// stalling the scheduler behind a join. A failure here fails this
    /// session only.
    fn poll_saves(&mut self) -> Result<()>;

    /// Epilogue — drain saves, final subspace lift, eval. Consumes the
    /// loop state; calling `step()` afterwards errors.
    fn finish(&mut self) -> Result<SessionSummary>;
}

/// [`TrainSession`] over [`FinetuneTrainer`] — the serve daemon's
/// tenant workload.
pub struct FinetuneSession {
    trainer: FinetuneTrainer,
    lp: Option<FinetuneLoop>,
    total: u64,
    result: Option<FinetuneResult>,
}

impl FinetuneSession {
    pub fn new(rt: &mut Runtime, artifacts_dir: &Path, cfg: FinetuneConfig) -> Result<Self> {
        Self::with_base(rt, artifacts_dir, cfg, None)
    }

    /// Build a session whose initial parameters come from `base` (a
    /// copy-on-write checkout of a cached base model) instead of
    /// re-reading `artifacts/`. `None` falls back to the standalone
    /// load path.
    pub fn with_base(
        rt: &mut Runtime,
        artifacts_dir: &Path,
        cfg: FinetuneConfig,
        base: Option<ParamStore>,
    ) -> Result<Self> {
        let total = cfg.steps;
        let mut trainer = FinetuneTrainer::with_base(rt, artifacts_dir, cfg, base)?;
        let lp = trainer.begin()?;
        Ok(FinetuneSession { trainer, lp: Some(lp), total, result: None })
    }

    /// Full result of a finished session (None before `finish`).
    pub fn result(&self) -> Option<&FinetuneResult> {
        self.result.as_ref()
    }

    pub fn into_result(self) -> Option<FinetuneResult> {
        self.result
    }
}

impl TrainSession for FinetuneSession {
    fn step(&mut self) -> Result<SessionStatus> {
        let lp = self.lp.as_mut().context("finetune session already finished")?;
        if self.trainer.step_once(lp)? {
            Ok(SessionStatus::Running)
        } else {
            Ok(SessionStatus::StepsExhausted)
        }
    }

    fn progress(&self) -> (u64, u64) {
        (self.lp.as_ref().map_or(self.total, |l| l.step()), self.total)
    }

    fn poll_saves(&mut self) -> Result<()> {
        self.trainer.poll_saves()
    }

    fn finish(&mut self) -> Result<SessionSummary> {
        let lp = self.lp.take().context("finetune session already finished")?;
        let steps_done = lp.step();
        let res = self.trainer.finish_run(lp)?;
        let summary = SessionSummary {
            kind: "finetune",
            metric: Some(res.accuracy),
            tail_loss: res.log.tail_mean_loss(10),
            steps_done,
        };
        self.result = Some(res);
        Ok(summary)
    }
}

/// [`TrainSession`] over [`PretrainTrainer`]. The daemon currently
/// schedules fine-tune tenants only, but the standalone `pretrain`
/// subcommand drives this same seam, keeping both trainers on one
/// step-loop shape.
pub struct PretrainSession {
    trainer: PretrainTrainer,
    lp: Option<PretrainLoop>,
    total: u64,
    result: Option<PretrainResult>,
}

impl PretrainSession {
    pub fn new(rt: &mut Runtime, artifacts_dir: &Path, cfg: PretrainConfig) -> Result<Self> {
        let total = cfg.steps;
        let mut trainer = PretrainTrainer::new(rt, artifacts_dir, cfg)?;
        let lp = trainer.begin()?;
        Ok(PretrainSession { trainer, lp: Some(lp), total, result: None })
    }

    pub fn result(&self) -> Option<&PretrainResult> {
        self.result.as_ref()
    }

    pub fn into_result(self) -> Option<PretrainResult> {
        self.result
    }
}

impl TrainSession for PretrainSession {
    fn step(&mut self) -> Result<SessionStatus> {
        let lp = self.lp.as_mut().context("pretrain session already finished")?;
        if self.trainer.step_once(lp)? {
            Ok(SessionStatus::Running)
        } else {
            Ok(SessionStatus::StepsExhausted)
        }
    }

    fn progress(&self) -> (u64, u64) {
        (self.lp.as_ref().map_or(self.total, |l| l.step()), self.total)
    }

    fn poll_saves(&mut self) -> Result<()> {
        self.trainer.poll_saves()
    }

    fn finish(&mut self) -> Result<SessionSummary> {
        let lp = self.lp.take().context("pretrain session already finished")?;
        let steps_done = lp.step();
        let res = self.trainer.finish_run(lp)?;
        let summary = SessionSummary {
            kind: "pretrain",
            metric: res.final_eval_loss.map(f64::from),
            tail_loss: res.log.tail_mean_loss(10),
            steps_done,
        };
        self.result = Some(res);
        Ok(summary)
    }
}
