//! Step records + CSV emission. The figure harnesses (`exp/`) turn
//! these logs into the paper's loss-curve series.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// One training-step record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    pub grad_norm: f32,
    /// Wall-clock seconds for this step (artifact execution + L3 work).
    pub step_time_s: f64,
}

/// Accumulated log with aggregate helpers.
#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
    /// (step, eval metric) pairs — eval loss for LM, accuracy for CLF.
    pub evals: Vec<(u64, f32)>,
}

impl MetricsLog {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn push_eval(&mut self, step: u64, value: f32) {
        self.evals.push((step, value));
    }

    pub fn final_train_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `n` steps (smoother than the last point).
    pub fn tail_mean_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let k = n.min(self.records.len());
        let s: f32 = self.records[self.records.len() - k..].iter().map(|r| r.loss).sum();
        Some(s / k as f32)
    }

    /// Mean step time, excluding the first `warmup` steps (compile and
    /// cache effects).
    pub fn mean_step_time(&self, warmup: usize) -> Option<f64> {
        if self.records.len() <= warmup {
            return None;
        }
        let xs = &self.records[warmup..];
        Some(xs.iter().map(|r| r.step_time_s).sum::<f64>() / xs.len() as f64)
    }

    /// Write `step,loss,lr,grad_norm,step_time_s` CSV (truncating).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        self.write_csv_with(path, false)
    }

    /// [`Self::write_csv`] with an append mode for resumed runs: the log
    /// only holds post-resume records, so truncate-recreate (the old
    /// behaviour) silently dropped every pre-resume row. With
    /// `append = true` the new rows extend the existing file (header
    /// written only when the file is fresh).
    pub fn write_csv_with(&self, path: &Path, append: bool) -> Result<()> {
        write_rows(
            path,
            append,
            "step,loss,lr,grad_norm,step_time_s",
            self.records.iter().map(|r| {
                format!("{},{},{},{},{}", r.step, r.loss, r.lr, r.grad_norm, r.step_time_s)
            }),
        )
    }

    /// Write `step,value` CSV of the eval series (truncating).
    pub fn write_eval_csv(&self, path: &Path) -> Result<()> {
        self.write_eval_csv_with(path, false)
    }

    /// Append-capable eval-series writer — same resume contract as
    /// [`Self::write_csv_with`].
    pub fn write_eval_csv_with(&self, path: &Path, append: bool) -> Result<()> {
        write_rows(
            path,
            append,
            "step,value",
            self.evals.iter().map(|(s, v)| format!("{s},{v}")),
        )
    }
}

fn write_rows(
    path: &Path,
    append: bool,
    header: &str,
    rows: impl Iterator<Item = String>,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let fresh = !append || !path.exists();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(append)
        .write(true)
        .truncate(!append)
        .open(path)?;
    if fresh {
        writeln!(f, "{header}")?;
    }
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, t: f64) -> StepRecord {
        StepRecord { step, loss, lr: 1e-3, grad_norm: 1.0, step_time_s: t }
    }

    #[test]
    fn aggregates() {
        let mut log = MetricsLog::default();
        for i in 0..10 {
            log.push(rec(i, 10.0 - i as f32, if i == 0 { 5.0 } else { 0.1 }));
        }
        assert_eq!(log.final_train_loss(), Some(1.0));
        assert!((log.tail_mean_loss(2).unwrap() - 1.5).abs() < 1e-6);
        // warmup exclusion drops the 5.0 outlier
        assert!((log.mean_step_time(1).unwrap() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut log = MetricsLog::default();
        log.push(rec(0, 3.0, 0.5));
        log.push_eval(0, 0.25);
        let dir = std::env::temp_dir().join("lowrank_sge_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("train.csv");
        let p2 = dir.join("eval.csv");
        log.write_csv(&p1).unwrap();
        log.write_eval_csv(&p2).unwrap();
        let train = std::fs::read_to_string(&p1).unwrap();
        assert!(train.starts_with("step,loss"));
        assert_eq!(train.lines().count(), 2);
        let eval = std::fs::read_to_string(&p2).unwrap();
        assert!(eval.contains("0,0.25"));
    }

    #[test]
    fn resume_appends_instead_of_dropping_the_earlier_series() {
        // regression: resumed runs hold only post-resume records, and the
        // truncate-recreate writers used to drop the pre-resume rows
        let dir = std::env::temp_dir().join("lowrank_sge_metrics_append_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("train.csv");
        let p2 = dir.join("eval.csv");
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);

        // first run: steps 0..3
        let mut first = MetricsLog::default();
        for i in 0..3 {
            first.push(rec(i, 5.0, 0.1));
        }
        first.push_eval(2, 0.5);
        first.write_csv_with(&p1, true).unwrap(); // fresh file → header
        first.write_eval_csv_with(&p2, true).unwrap();

        // resumed run: steps 3..5 only
        let mut resumed = MetricsLog::default();
        for i in 3..5 {
            resumed.push(rec(i, 4.0, 0.1));
        }
        resumed.push_eval(4, 0.75);
        resumed.write_csv_with(&p1, true).unwrap();
        resumed.write_eval_csv_with(&p2, true).unwrap();

        let train = std::fs::read_to_string(&p1).unwrap();
        let lines: Vec<&str> = train.lines().collect();
        assert_eq!(lines.len(), 6, "header + 5 rows, got: {train}");
        assert_eq!(lines[0], "step,loss,lr,grad_norm,step_time_s");
        assert!(lines[1].starts_with("0,") && lines[5].starts_with("4,"), "{train}");
        let eval = std::fs::read_to_string(&p2).unwrap();
        assert!(eval.contains("2,0.5") && eval.contains("4,0.75"), "{eval}");

        // the truncating default still recreates from scratch
        resumed.write_csv(&p1).unwrap();
        assert_eq!(std::fs::read_to_string(&p1).unwrap().lines().count(), 3);
    }

    #[test]
    fn empty_log_returns_none() {
        let log = MetricsLog::default();
        assert!(log.final_train_loss().is_none());
        assert!(log.mean_step_time(0).is_none());
    }
}
