//! Per-matrix subspace state: the (B, V) pair of Algorithm 1 plus its
//! Adam moments, wired to the artifact input/output slots by name.
//!
//! The manifest naming convention (aot.py) is the contract:
//!   inputs  `params[<name>]`, `bs[<name>]`, `vs[<name>]`, `tokens`, …
//!   outputs `out[0]` (loss), `out[1][<name>]` (dB), `out[2][<name>]`
//!   (full-rank gradients for embeddings/norms — LM artifacts only).
//!
//! B and V are `Arc`-backed so the trainers stage them into artifact
//! inputs by reference-count bump (zero-copy); mutation goes through
//! `Arc::make_mut`, which is in-place whenever no staged clone is alive
//! — i.e. always, in the steady-state step loop.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ckpt::Checkpointable;
use crate::kernel;
use crate::model::ParamStore;
use crate::optim::{Adam, AdamConfig};
use crate::projection::{sample_batch, ProjectorKind};
use crate::rng::Rng;
use crate::runtime::ArtifactManifest;

/// One reparameterized matrix W (m×n) with its auxiliary B (m×r) and
/// projector V (n×r).
pub struct MatrixSlot {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub r: usize,
    /// Artifact input slot of B (usize::MAX if the artifact has no B
    /// input, e.g. the ZO artifacts where B ≡ ±σZ).
    pub b_input: usize,
    /// Artifact input slot of V.
    pub v_input: usize,
    /// Artifact output slot of dB (usize::MAX for ZO artifacts).
    pub db_output: usize,
    /// Position of W in the [`ParamStore`].
    pub param_pos: usize,
    /// Auxiliary B (m×r), shared with the staging path (see module docs).
    pub b: Arc<Vec<f32>>,
    /// Projector V (n×r), shared with the staging path.
    pub v: Arc<Vec<f32>>,
    pub adam: Adam,
}

/// A full-rank trainable (embedding / norm) with its gradient output.
pub struct FullSlot {
    pub name: String,
    pub param_pos: usize,
    pub dout: usize,
    pub adam: Adam,
}

/// All subspace state for one artifact.
pub struct SubspaceSet {
    pub slots: Vec<MatrixSlot>,
    pub kind: ProjectorKind,
    pub c: f64,
    outer_iterations: u64,
    /// Reusable view staging for the parallel lift fan-out
    /// ([`ParamStore::f32_mut_many_with`]).
    lift_scratch: crate::model::MutManyScratch,
}

fn bracket_name(s: &str, prefix: &str) -> Option<String> {
    // "bs[layer0.w1]" with prefix "bs" → "layer0.w1"
    s.strip_prefix(prefix)
        .and_then(|rest| rest.strip_prefix('['))
        .and_then(|rest| rest.strip_suffix(']'))
        .map(|x| x.to_string())
}

impl SubspaceSet {
    /// Assemble directly from slots — the manifest-free path the engine
    /// golden tests and allocation benches use.
    pub fn from_slots(slots: Vec<MatrixSlot>, kind: ProjectorKind, c: f64) -> Self {
        assert!(!slots.is_empty(), "a SubspaceSet needs at least one slot");
        SubspaceSet {
            slots,
            kind,
            c,
            outer_iterations: 0,
            lift_scratch: crate::model::MutManyScratch::new(),
        }
    }

    /// Build from a manifest that has `bs[...]`/`vs[...]` inputs (the
    /// grad-style artifacts).
    pub fn from_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "bs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("B slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let db_output = manifest
                .outputs
                .iter()
                .position(|s| s.name == format!("out[1][{name}]"))
                .unwrap_or(usize::MAX);
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index,
                v_input,
                db_output,
                param_pos,
                b: Arc::new(vec![0.0; m * r]),
                v: Arc::new(vec![0.0; n * r]),
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no bs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet {
            slots,
            kind,
            c,
            outer_iterations: 0,
            lift_scratch: crate::model::MutManyScratch::new(),
        })
    }

    /// Build for ZO artifacts: `zs[...]`/`vs[...]` inputs, no B input
    /// and no dB output (the estimator is formed in Rust).
    pub fn from_zo_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "zs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("Z slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index, // the Z slot doubles as the "B" input
                v_input,
                db_output: usize::MAX,
                param_pos,
                b: Arc::new(vec![0.0; m * r]),
                v: Arc::new(vec![0.0; n * r]),
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no zs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet {
            slots,
            kind,
            c,
            outer_iterations: 0,
            lift_scratch: crate::model::MutManyScratch::new(),
        })
    }

    /// Resample every V (Algorithm 1 line 3): B ← 0, fresh V, Adam
    /// moments reset (they live in the old subspace's coordinates).
    ///
    /// Draws fan out across the kernel pool via
    /// [`crate::projection::sample_batch`]: one forked child stream per
    /// slot (in slot order), so the result depends only on `rng` — not
    /// on the thread count.
    pub fn resample(&mut self, rng: &mut Rng) {
        let _span = crate::obs::span("engine", "resample");
        let dims: Vec<(usize, usize)> = self.slots.iter().map(|s| (s.n, s.r)).collect();
        let vs = sample_batch(self.kind, &dims, self.c, None, rng);
        for (slot, v) in self.slots.iter_mut().zip(vs) {
            for (dst, src) in Arc::make_mut(&mut slot.v).iter_mut().zip(&v.data) {
                *dst = *src as f32;
            }
            Arc::make_mut(&mut slot.b).iter_mut().for_each(|x| *x = 0.0);
            slot.adam.reset();
        }
        self.outer_iterations += 1;
    }

    /// Lift Θ ← Θ + B·Vᵀ into the store and zero B (Algorithm 1 line 8).
    ///
    /// The per-matrix lifts are independent (disjoint Θ tensors), so
    /// they fan out across the kernel pool — one task per slot, each
    /// running the serial GEMM body so the parallelism stays one level
    /// deep and the bytes match a serial pass exactly.
    pub fn lift(&mut self, store: &mut ParamStore) -> Result<()> {
        let _span = crate::obs::span("engine", "lift");
        let pool = kernel::global();
        if pool.threads() == 1 {
            // inline serial path: no boxed tasks, no view staging — the
            // zero-allocation contract's route (tests/engine_alloc.rs)
            for slot in &self.slots {
                let theta = store.f32_mut(slot.param_pos)?;
                kernel::serial::gemm_nt(
                    1.0f32,
                    slot.b.as_slice(),
                    slot.v.as_slice(),
                    theta,
                    slot.m,
                    slot.n,
                    slot.r,
                );
            }
        } else {
            let positions: Vec<usize> = self.slots.iter().map(|s| s.param_pos).collect();
            let slots = &self.slots;
            store.f32_mut_many_with(
                &positions,
                &mut self.lift_scratch,
                |thetas: &mut Vec<&mut [f32]>| {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    for (slot, theta) in slots.iter().zip(thetas.drain(..)) {
                        let (m, n, r) = (slot.m, slot.n, slot.r);
                        let (b, v) = (slot.b.as_slice(), slot.v.as_slice());
                        tasks.push(Box::new(move || {
                            kernel::serial::gemm_nt(1.0f32, b, v, theta, m, n, r)
                        }));
                    }
                    pool.run(tasks);
                },
            )?;
        }
        if crate::obs::metrics::enabled() {
            // per-layer lift residual ‖B‖_F — how much subspace motion
            // each outer iteration folded into Θ (read back from the
            // metrics series as `lift_b_norm[<layer>]`)
            for slot in &self.slots {
                let norm =
                    slot.b.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
                crate::obs::metrics::record_value(&format!("lift_b_norm[{}]", slot.name), norm);
            }
        }
        for slot in &mut self.slots {
            Arc::make_mut(&mut slot.b).iter_mut().for_each(|x| *x = 0.0);
        }
        Ok(())
    }

    /// One Adam step per slot's B, fanned out across the kernel pool.
    /// Slots are independent, so parallel equals serial bitwise.
    /// Generic over the gradient container (`Vec<f32>`, `&[f32]`, …) so
    /// callers holding borrowed artifact outputs never have to copy.
    pub fn adam_step_all<G: AsRef<[f32]> + Sync>(&mut self, grads: &[G], lr: f32) {
        assert_eq!(grads.len(), self.slots.len(), "one gradient per slot");
        let pool = kernel::global();
        if pool.threads() == 1 {
            // inline serial path: boxing the tasks would allocate, and
            // this runs once per IPA step inside the zero-allocation
            // contract (tests/engine_alloc.rs)
            for (slot, g) in self.slots.iter_mut().zip(grads) {
                slot.adam.step(Arc::make_mut(&mut slot.b), g.as_ref(), lr);
            }
            return;
        }
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (slot, g) in self.slots.iter_mut().zip(grads) {
            tasks.push(Box::new(move || {
                slot.adam.step(Arc::make_mut(&mut slot.b), g.as_ref(), lr)
            }));
        }
        pool.run(tasks);
    }

    pub fn outer_iterations(&self) -> u64 {
        self.outer_iterations
    }

    /// Σ m·r — total subspace parameter count (the memory story).
    pub fn b_elements(&self) -> usize {
        self.slots.iter().map(|s| s.m * s.r).sum()
    }

    /// Bytes of optimizer state held by the subspace Adam instances.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.adam.state_bytes()).sum()
    }
}

/// Checkpointing: per slot the live B and V matrices plus the nested
/// Adam moments (`adam[<name>].{m,v,t}` — `t` is the per-slot inner-step
/// counter), and the outer-iteration count. Restoring mid-outer-iteration
/// continues in the *same* subspace V with the same optimizer momentum,
/// which is what makes a resumed run track the uninterrupted trajectory.
impl crate::ckpt::Checkpointable for SubspaceSet {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_u64s("outer_iterations", &[self.outer_iterations]);
        for slot in &self.slots {
            sd.put_tensor(
                format!("b[{}]", slot.name),
                crate::runtime::HostTensor::f32_shared(vec![slot.m, slot.r], slot.b.clone()),
            );
            sd.put_tensor(
                format!("v[{}]", slot.name),
                crate::runtime::HostTensor::f32_shared(vec![slot.n, slot.r], slot.v.clone()),
            );
            sd.merge_prefixed(&format!("adam[{}].", slot.name), slot.adam.state_dict());
        }
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> Result<()> {
        // 1 scalar + per slot: b, v, adam.{m,v,t}
        let want = 1 + 5 * self.slots.len();
        if sd.len() != want {
            bail!("subspace checkpoint has {} tensors, expected {want}", sd.len());
        }
        let outer = sd.u64("outer_iterations")?;
        // validate every slot's shapes/dtypes, staging the payloads by
        // Arc share (no per-slot copy — the live buffers unshare lazily
        // on first mutation) …
        let mut staged_b: Vec<Arc<Vec<f32>>> = Vec::with_capacity(self.slots.len());
        let mut staged_v: Vec<Arc<Vec<f32>>> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let b_t = sd.tensor(&format!("b[{}]", slot.name))?;
            if b_t.shape() != [slot.m, slot.r] {
                bail!(
                    "subspace checkpoint b[{}] has shape {:?}, expected [{}, {}]",
                    slot.name,
                    b_t.shape(),
                    slot.m,
                    slot.r
                );
            }
            staged_b.push(b_t.f32_arc()?);
            let v_t = sd.tensor(&format!("v[{}]", slot.name))?;
            if v_t.shape() != [slot.n, slot.r] {
                bail!(
                    "subspace checkpoint v[{}] has shape {:?}, expected [{}, {}]",
                    slot.name,
                    v_t.shape(),
                    slot.n,
                    slot.r
                );
            }
            staged_v.push(v_t.f32_arc()?);
        }
        // … then apply
        for ((slot, b), v) in self.slots.iter_mut().zip(staged_b).zip(staged_v) {
            slot.b = b;
            slot.v = v;
            slot.adam
                .load_state(&sd.extract_prefixed(&format!("adam[{}].", slot.name)))
                .with_context(|| format!("subspace slot {}", slot.name))?;
        }
        self.outer_iterations = outer;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostTensor, TensorSpec};

    const TOY_MANIFEST: &str = "\
artifact = toy_grad
num_inputs = 5
num_outputs = 2
input 0 params[embed] f32 8x4
input 1 params[w0] f32 4x4
input 2 bs[w0] f32 4x2
input 3 vs[w0] f32 4x2
input 4 tokens i32 2x3
output 0 out[0] f32 scalar
output 1 out[1][w0] f32 4x2
";

    fn toy_set() -> SubspaceSet {
        let manifest = ArtifactManifest::parse(TOY_MANIFEST).unwrap();
        let specs: Vec<TensorSpec> = manifest
            .inputs
            .iter()
            .take(2)
            .cloned()
            .collect();
        let tensors = vec![
            HostTensor::f32(vec![8, 4], vec![0.0; 32]),
            HostTensor::f32(vec![4, 4], vec![0.0; 16]),
        ];
        let store = ParamStore::for_test(specs, tensors);
        SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0, AdamConfig::default())
            .unwrap()
    }

    #[test]
    fn checkpoint_roundtrip_restores_b_v_and_moments_bitwise() {
        let mut src = toy_set();
        let mut rng = Rng::new(5);
        src.resample(&mut rng);
        // advance the slot optimizer so moments and t are non-trivial
        for k in 0..3 {
            let g: Vec<f32> = (0..8).map(|i| (k * 8 + i) as f32 * 0.1 - 0.3).collect();
            let slot = &mut src.slots[0];
            slot.adam.step(std::sync::Arc::make_mut(&mut slot.b), &g, 1e-2);
        }
        let sd = src.state_dict();

        let mut dst = toy_set();
        dst.load_state(&sd).unwrap();
        assert_eq!(dst.outer_iterations(), 1);
        for (a, b) in src.slots.iter().zip(&dst.slots) {
            assert_eq!(a.adam.steps_taken(), b.adam.steps_taken());
            for (x, y) in a.b.iter().zip(&b.b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.v.iter().zip(&b.v) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a truncated dict is rejected
        let partial = sd.extract_prefixed("");
        assert_eq!(partial.len(), sd.len());
        let mut missing = crate::ckpt::StateDict::new();
        missing.put_u64s("outer_iterations", &[1]);
        assert!(dst.load_state(&missing).is_err());
    }

    const TRIPLE_MANIFEST: &str = "\
artifact = toy3_grad
num_inputs = 10
num_outputs = 4
input 0 params[w0] f32 40x24
input 1 params[w1] f32 24x24
input 2 params[w2] f32 48x16
input 3 bs[w0] f32 40x3
input 4 vs[w0] f32 24x3
input 5 bs[w1] f32 24x2
input 6 vs[w1] f32 24x2
input 7 bs[w2] f32 48x4
input 8 vs[w2] f32 16x4
input 9 tokens i32 2x3
output 0 out[0] f32 scalar
output 1 out[1][w0] f32 40x3
output 2 out[1][w1] f32 24x2
output 3 out[1][w2] f32 48x4
";

    fn triple_store() -> ParamStore {
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let specs: Vec<TensorSpec> = manifest.inputs.iter().take(3).cloned().collect();
        let tensors = specs
            .iter()
            .map(|s| {
                let len: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..len).map(|i| (i as f32) * 1e-3 - 0.2).collect(),
                )
            })
            .collect();
        ParamStore::for_test(specs, tensors)
    }

    /// Collect every file under `dir` as (relative path, bytes).
    fn dir_bytes(dir: &std::path::Path) -> std::collections::BTreeMap<String, Vec<u8>> {
        fn walk(
            root: &std::path::Path,
            dir: &std::path::Path,
            out: &mut std::collections::BTreeMap<String, Vec<u8>>,
        ) {
            for entry in std::fs::read_dir(dir).unwrap() {
                let path = entry.unwrap().path();
                if path.is_dir() {
                    walk(root, &path, out);
                } else {
                    let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                    out.insert(rel, std::fs::read(&path).unwrap());
                }
            }
        }
        let mut out = std::collections::BTreeMap::new();
        walk(dir, dir, &mut out);
        out
    }

    /// Drive the full slot fan-out (resample → per-slot Adam steps →
    /// lift) at a given pool size, returning the final parameter bits
    /// and the committed checkpoint bytes.
    fn run_slot_fanout(threads: usize) -> (Vec<u32>, std::collections::BTreeMap<String, Vec<u8>>) {
        crate::kernel::set_global_threads(threads);
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let mut store = triple_store();
        let mut set = SubspaceSet::from_manifest(
            &manifest,
            &store,
            ProjectorKind::Stiefel,
            1.0,
            AdamConfig::default(),
        )
        .unwrap();
        let mut rng = Rng::new(4242);
        for outer in 0..2u64 {
            set.resample(&mut rng);
            for step in 0..3u64 {
                let grads: Vec<Vec<f32>> = set
                    .slots
                    .iter()
                    .enumerate()
                    .map(|(si, s)| {
                        (0..s.m * s.r)
                            .map(|i| (((outer * 100 + step * 31 + si as u64 * 7 + i as u64) as f32)
                                * 0.01)
                                .sin())
                            .collect()
                    })
                    .collect();
                set.adam_step_all(&grads, 1e-2);
            }
            set.lift(&mut store).unwrap();
        }
        let bits: Vec<u32> = (0..store.len())
            .flat_map(|i| store.f32(i).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>())
            .collect();
        // PID-unique path so concurrent test binaries on one machine
        // cannot race each other's remove/save/read cycle
        let dir = std::env::temp_dir()
            .join(format!("lowrank_sge_slot_fanout_p{}_t{threads}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        crate::ckpt::save_checkpoint(
            &dir,
            1,
            &[],
            &[("params", store.state_dict()), ("subspace", set.state_dict())],
            0,
        )
        .unwrap();
        let bytes = dir_bytes(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        (bits, bytes)
    }

    #[test]
    fn slot_fanout_is_thread_count_invariant() {
        // Satellite: a 3-matrix artifact stepped with threads = 1 and
        // threads = 4 must produce identical ParamStore bytes and
        // identical checkpoint shards.
        let _guard = crate::kernel::pool::global_test_guard();
        let prev_threads = crate::kernel::global_threads();
        let (bits_serial, ckpt_serial) = run_slot_fanout(1);
        let (bits_par, ckpt_par) = run_slot_fanout(4);
        // restore so the LOWRANK_THREADS-driven CI legs keep their
        // configured pool size for the rest of the suite
        crate::kernel::set_global_threads(prev_threads);
        assert!(!bits_serial.is_empty());
        assert_eq!(bits_serial, bits_par, "ParamStore bytes diverged across thread counts");
        assert_eq!(
            ckpt_serial.keys().collect::<Vec<_>>(),
            ckpt_par.keys().collect::<Vec<_>>()
        );
        for (name, bytes) in &ckpt_serial {
            assert_eq!(bytes, &ckpt_par[name], "checkpoint shard {name} diverged");
        }
        assert!(ckpt_serial.keys().any(|k| k.contains("MANIFEST")));
    }

    #[test]
    fn bracket_name_parses() {
        assert_eq!(bracket_name("bs[layer0.w1]", "bs").as_deref(), Some("layer0.w1"));
        assert_eq!(bracket_name("vs[x]", "vs").as_deref(), Some("x"));
        assert_eq!(bracket_name("tokens", "bs"), None);
        assert_eq!(bracket_name("bs[unclosed", "bs"), None);
        // params[...] must not match the bs prefix
        assert_eq!(bracket_name("params[embed]", "bs"), None);
    }
}
