//! Per-matrix subspace state: the (B, V) pair of Algorithm 1 plus its
//! Adam moments, wired to the artifact input/output slots by name.
//!
//! The manifest naming convention (aot.py) is the contract:
//!   inputs  `params[<name>]`, `bs[<name>]`, `vs[<name>]`, `tokens`, …
//!   outputs `out[0]` (loss), `out[1][<name>]` (dB), `out[2][<name>]`
//!   (full-rank gradients for embeddings/norms — LM artifacts only).

use anyhow::{bail, Context, Result};

use crate::ckpt::Checkpointable;
use crate::model::{lift_into, ParamStore};
use crate::optim::{Adam, AdamConfig};
use crate::projection::{build_sampler, ProjectorKind};
use crate::rng::Rng;
use crate::runtime::ArtifactManifest;

/// One reparameterized matrix W (m×n) with its auxiliary B (m×r) and
/// projector V (n×r).
pub struct MatrixSlot {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub r: usize,
    /// Artifact input slot of B (usize::MAX if the artifact has no B
    /// input, e.g. the ZO artifacts where B ≡ ±σZ).
    pub b_input: usize,
    /// Artifact input slot of V.
    pub v_input: usize,
    /// Artifact output slot of dB (usize::MAX for ZO artifacts).
    pub db_output: usize,
    /// Position of W in the [`ParamStore`].
    pub param_pos: usize,
    pub b: Vec<f32>,
    pub v: Vec<f32>,
    pub adam: Adam,
}

/// A full-rank trainable (embedding / norm) with its gradient output.
pub struct FullSlot {
    pub name: String,
    pub param_pos: usize,
    pub dout: usize,
    pub adam: Adam,
}

/// All subspace state for one artifact.
pub struct SubspaceSet {
    pub slots: Vec<MatrixSlot>,
    pub kind: ProjectorKind,
    pub c: f64,
    outer_iterations: u64,
}

fn bracket_name(s: &str, prefix: &str) -> Option<String> {
    // "bs[layer0.w1]" with prefix "bs" → "layer0.w1"
    s.strip_prefix(prefix)
        .and_then(|rest| rest.strip_prefix('['))
        .and_then(|rest| rest.strip_suffix(']'))
        .map(|x| x.to_string())
}

impl SubspaceSet {
    /// Build from a manifest that has `bs[...]`/`vs[...]` inputs (the
    /// grad-style artifacts).
    pub fn from_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "bs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("B slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let db_output = manifest
                .outputs
                .iter()
                .position(|s| s.name == format!("out[1][{name}]"))
                .unwrap_or(usize::MAX);
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index,
                v_input,
                db_output,
                param_pos,
                b: vec![0.0; m * r],
                v: vec![0.0; n * r],
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no bs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet { slots, kind, c, outer_iterations: 0 })
    }

    /// Build for ZO artifacts: `zs[...]`/`vs[...]` inputs, no B input
    /// and no dB output (the estimator is formed in Rust).
    pub fn from_zo_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "zs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("Z slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index, // the Z slot doubles as the "B" input
                v_input,
                db_output: usize::MAX,
                param_pos,
                b: vec![0.0; m * r],
                v: vec![0.0; n * r],
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no zs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet { slots, kind, c, outer_iterations: 0 })
    }

    /// Resample every V (Algorithm 1 line 3): B ← 0, fresh V, Adam
    /// moments reset (they live in the old subspace's coordinates).
    pub fn resample(&mut self, rng: &mut Rng) {
        for slot in &mut self.slots {
            let mut sampler = build_sampler(self.kind, slot.n, slot.r, self.c, None);
            let v = sampler.sample(rng);
            for (dst, src) in slot.v.iter_mut().zip(&v.data) {
                *dst = *src as f32;
            }
            slot.b.iter_mut().for_each(|x| *x = 0.0);
            slot.adam.reset();
        }
        self.outer_iterations += 1;
    }

    /// Lift Θ ← Θ + B·Vᵀ into the store and zero B (Algorithm 1 line 8).
    pub fn lift(&mut self, store: &mut ParamStore) -> Result<()> {
        for slot in &mut self.slots {
            let theta = store.f32_mut(slot.param_pos)?;
            lift_into(theta, &slot.b, &slot.v, slot.m, slot.n, slot.r);
            slot.b.iter_mut().for_each(|x| *x = 0.0);
        }
        Ok(())
    }

    pub fn outer_iterations(&self) -> u64 {
        self.outer_iterations
    }

    /// Σ m·r — total subspace parameter count (the memory story).
    pub fn b_elements(&self) -> usize {
        self.slots.iter().map(|s| s.m * s.r).sum()
    }

    /// Bytes of optimizer state held by the subspace Adam instances.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.adam.state_bytes()).sum()
    }
}

/// Checkpointing: per slot the live B and V matrices plus the nested
/// Adam moments (`adam[<name>].{m,v,t}` — `t` is the per-slot inner-step
/// counter), and the outer-iteration count. Restoring mid-outer-iteration
/// continues in the *same* subspace V with the same optimizer momentum,
/// which is what makes a resumed run track the uninterrupted trajectory.
impl crate::ckpt::Checkpointable for SubspaceSet {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_u64s("outer_iterations", &[self.outer_iterations]);
        for slot in &self.slots {
            sd.put_f32(format!("b[{}]", slot.name), vec![slot.m, slot.r], slot.b.clone());
            sd.put_f32(format!("v[{}]", slot.name), vec![slot.n, slot.r], slot.v.clone());
            sd.merge_prefixed(&format!("adam[{}].", slot.name), slot.adam.state_dict());
        }
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> Result<()> {
        // 1 scalar + per slot: b, v, adam.{m,v,t}
        let want = 1 + 5 * self.slots.len();
        if sd.len() != want {
            bail!("subspace checkpoint has {} tensors, expected {want}", sd.len());
        }
        let outer = sd.u64("outer_iterations")?;
        let mut staged: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let b_t = sd.tensor(&format!("b[{}]", slot.name))?;
            if b_t.shape() != [slot.m, slot.r] {
                bail!(
                    "subspace checkpoint b[{}] has shape {:?}, expected [{}, {}]",
                    slot.name,
                    b_t.shape(),
                    slot.m,
                    slot.r
                );
            }
            let v_t = sd.tensor(&format!("v[{}]", slot.name))?;
            if v_t.shape() != [slot.n, slot.r] {
                bail!(
                    "subspace checkpoint v[{}] has shape {:?}, expected [{}, {}]",
                    slot.name,
                    v_t.shape(),
                    slot.n,
                    slot.r
                );
            }
            staged.push((b_t.as_f32()?.to_vec(), v_t.as_f32()?.to_vec()));
        }
        // all validated — now apply
        for (slot, (b, v)) in self.slots.iter_mut().zip(staged) {
            slot.b = b;
            slot.v = v;
            slot.adam
                .load_state(&sd.extract_prefixed(&format!("adam[{}].", slot.name)))
                .with_context(|| format!("subspace slot {}", slot.name))?;
        }
        self.outer_iterations = outer;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostTensor, TensorSpec};

    const TOY_MANIFEST: &str = "\
artifact = toy_grad
num_inputs = 5
num_outputs = 2
input 0 params[embed] f32 8x4
input 1 params[w0] f32 4x4
input 2 bs[w0] f32 4x2
input 3 vs[w0] f32 4x2
input 4 tokens i32 2x3
output 0 out[0] f32 scalar
output 1 out[1][w0] f32 4x2
";

    fn toy_set() -> SubspaceSet {
        let manifest = ArtifactManifest::parse(TOY_MANIFEST).unwrap();
        let specs: Vec<TensorSpec> = manifest
            .inputs
            .iter()
            .take(2)
            .cloned()
            .collect();
        let tensors = vec![
            HostTensor::f32(vec![8, 4], vec![0.0; 32]),
            HostTensor::f32(vec![4, 4], vec![0.0; 16]),
        ];
        let store = ParamStore::for_test(specs, tensors);
        SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0, AdamConfig::default())
            .unwrap()
    }

    #[test]
    fn checkpoint_roundtrip_restores_b_v_and_moments_bitwise() {
        let mut src = toy_set();
        let mut rng = Rng::new(5);
        src.resample(&mut rng);
        // advance the slot optimizer so moments and t are non-trivial
        for k in 0..3 {
            let g: Vec<f32> = (0..8).map(|i| (k * 8 + i) as f32 * 0.1 - 0.3).collect();
            let slot = &mut src.slots[0];
            let mut b = std::mem::take(&mut slot.b);
            slot.adam.step(&mut b, &g, 1e-2);
            slot.b = b;
        }
        let sd = src.state_dict();

        let mut dst = toy_set();
        dst.load_state(&sd).unwrap();
        assert_eq!(dst.outer_iterations(), 1);
        for (a, b) in src.slots.iter().zip(&dst.slots) {
            assert_eq!(a.adam.steps_taken(), b.adam.steps_taken());
            for (x, y) in a.b.iter().zip(&b.b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.v.iter().zip(&b.v) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a truncated dict is rejected
        let partial = sd.extract_prefixed("");
        assert_eq!(partial.len(), sd.len());
        let mut missing = crate::ckpt::StateDict::new();
        missing.put_u64s("outer_iterations", &[1]);
        assert!(dst.load_state(&missing).is_err());
    }

    #[test]
    fn bracket_name_parses() {
        assert_eq!(bracket_name("bs[layer0.w1]", "bs").as_deref(), Some("layer0.w1"));
        assert_eq!(bracket_name("vs[x]", "vs").as_deref(), Some("x"));
        assert_eq!(bracket_name("tokens", "bs"), None);
        assert_eq!(bracket_name("bs[unclosed", "bs"), None);
        // params[...] must not match the bs prefix
        assert_eq!(bracket_name("params[embed]", "bs"), None);
    }
}
