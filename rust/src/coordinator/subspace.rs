//! Per-matrix subspace state: the (B, V) pair of Algorithm 1 plus its
//! Adam moments, wired to the artifact input/output slots by name.
//!
//! The manifest naming convention (aot.py) is the contract:
//!   inputs  `params[<name>]`, `bs[<name>]`, `vs[<name>]`, `tokens`, …
//!   outputs `out[0]` (loss), `out[1][<name>]` (dB), `out[2][<name>]`
//!   (full-rank gradients for embeddings/norms — LM artifacts only).
//!
//! B and V are `Arc`-backed so the trainers stage them into artifact
//! inputs by reference-count bump (zero-copy); mutation goes through
//! `Arc::make_mut`, which is in-place whenever no staged clone is alive
//! — i.e. always, in the steady-state step loop.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ckpt::Checkpointable;
use crate::kernel;
use crate::linalg::Mat;
use crate::model::ParamStore;
use crate::optim::{Adam, AdamConfig};
use crate::projection::{sample_batch, track_batch, ProjectorKind};
use crate::rng::Rng;
use crate::runtime::ArtifactManifest;

/// One reparameterized matrix W (m×n) with its auxiliary B (m×r) and
/// projector V (n×r).
///
/// `r` is the slot's *active* rank: the rank controller may shrink it
/// below the manifest rank `r_max` at a lazy-update boundary
/// ([`SubspaceSet::shrink_slot_rank`]). B, V, and the Adam moments are
/// always laid out compactly at the active rank — that is where the
/// memory and GEMM savings come from — while the artifact, whose input
/// shapes are baked into the compiled HLO, keeps seeing `[·, r_max]`
/// tensors through the zero-padded `stage_b`/`stage_v` pads (zero V
/// columns contribute nothing to W and produce exactly-zero dB
/// columns, so the padded execution equals the compact one).
pub struct MatrixSlot {
    pub name: String,
    pub m: usize,
    pub n: usize,
    /// Active rank (≤ `r_max`).
    pub r: usize,
    /// Manifest rank — the artifact-facing staging shape.
    pub r_max: usize,
    /// Artifact input slot of B (usize::MAX if the artifact has no B
    /// input, e.g. the ZO artifacts where B ≡ ±σZ).
    pub b_input: usize,
    /// Artifact input slot of V.
    pub v_input: usize,
    /// Artifact output slot of dB (usize::MAX for ZO artifacts).
    pub db_output: usize,
    /// Position of W in the [`ParamStore`].
    pub param_pos: usize,
    /// Auxiliary B (m×r), shared with the staging path (see module docs).
    pub b: Arc<Vec<f32>>,
    /// Projector V (n×r), shared with the staging path.
    pub v: Arc<Vec<f32>>,
    pub adam: Adam,
    /// Previous unit Stiefel frame Q (n×r, f64) when subspace tracking
    /// is on — the warm-start state of [`crate::projection::tracking`].
    /// Checkpointed at full f64 precision so a resumed tracked run
    /// reproduces the uninterrupted one bit for bit.
    pub frame: Option<Mat>,
    /// Zero-padded `[m, r_max]` staging pad, allocated on first shrink.
    pub stage_b: Option<Arc<Vec<f32>>>,
    /// Zero-padded `[n, r_max]` staging pad, allocated on first shrink.
    pub stage_v: Option<Arc<Vec<f32>>>,
}

impl MatrixSlot {
    /// Artifact-facing B tensor: the compact buffer at full rank, the
    /// zero-padded pad after a shrink (refresh with
    /// [`SubspaceSet::refresh_stage`] before staging).
    pub fn staged_b(&self) -> (Vec<usize>, Arc<Vec<f32>>) {
        match &self.stage_b {
            Some(pad) => (vec![self.m, self.r_max], Arc::clone(pad)),
            None => (vec![self.m, self.r], Arc::clone(&self.b)),
        }
    }

    /// Artifact-facing V tensor (see [`Self::staged_b`]).
    pub fn staged_v(&self) -> (Vec<usize>, Arc<Vec<f32>>) {
        match &self.stage_v {
            Some(pad) => (vec![self.n, self.r_max], Arc::clone(pad)),
            None => (vec![self.n, self.r], Arc::clone(&self.v)),
        }
    }

    fn refresh_stage_b(&mut self) {
        if let Some(pad) = &mut self.stage_b {
            let dst = Arc::make_mut(pad);
            for row in 0..self.m {
                dst[row * self.r_max..row * self.r_max + self.r]
                    .copy_from_slice(&self.b[row * self.r..(row + 1) * self.r]);
            }
        }
    }

    fn refresh_stage_v(&mut self) {
        if let Some(pad) = &mut self.stage_v {
            let dst = Arc::make_mut(pad);
            for row in 0..self.n {
                dst[row * self.r_max..row * self.r_max + self.r]
                    .copy_from_slice(&self.v[row * self.r..(row + 1) * self.r]);
            }
        }
    }
}

/// Compact a row-major `[rows, old_r]` buffer to `[rows, new_r]` in
/// place and release the tail capacity (the drop must show up in the
/// measured memory ledger, not just the analytical model).
fn compact_cols(buf: &mut Arc<Vec<f32>>, rows: usize, old_r: usize, new_r: usize) {
    let v = Arc::make_mut(buf);
    for row in 1..rows {
        // forward copy is safe: dst row·new_r+j ≤ src row·old_r+j
        v.copy_within(row * old_r..row * old_r + new_r, row * new_r);
    }
    v.truncate(rows * new_r);
    v.shrink_to_fit();
}

/// A full-rank trainable (embedding / norm) with its gradient output.
pub struct FullSlot {
    pub name: String,
    pub param_pos: usize,
    pub dout: usize,
    pub adam: Adam,
}

/// All subspace state for one artifact.
pub struct SubspaceSet {
    pub slots: Vec<MatrixSlot>,
    pub kind: ProjectorKind,
    pub c: f64,
    outer_iterations: u64,
    /// Warm-start schedule: 0 = every resample is a fresh Haar draw
    /// (the classic Algorithm 1 path, and the default for
    /// manifest-free construction); T ≥ 1 = tracked refreshes with a
    /// full Haar redraw every T-th resample. Only the Stiefel law
    /// tracks — other kinds always draw fresh.
    track_refresh: u64,
    /// Resamples since construction under the tracked schedule (drives
    /// the every-T full-refresh tick; checkpointed).
    track_age: u64,
    /// Per-slot lift residuals ‖B‖_F/√(m·r) from the most recent
    /// [`Self::lift`] — the rank controller's input signal.
    lift_residuals: Vec<f64>,
    /// Precomputed `lift_b_norm[<name>]` metric keys (built once here
    /// instead of a `format!` per slot per lift).
    lift_keys: Vec<String>,
    /// Precomputed `rank[<name>]` metric keys for controller decisions.
    rank_keys: Vec<String>,
    /// Precomputed `mse_ratio[<name>]` metric keys — the
    /// Theorem-2-normalized variance proxy [`crate::obs::quality`]
    /// exports per slot (kept here so every producer of the series
    /// spells the key the same way).
    mse_keys: Vec<String>,
    /// Reusable view staging for the parallel lift fan-out
    /// ([`ParamStore::f32_mut_many_with`]).
    lift_scratch: crate::model::MutManyScratch,
}

fn bracket_name(s: &str, prefix: &str) -> Option<String> {
    // "bs[layer0.w1]" with prefix "bs" → "layer0.w1"
    s.strip_prefix(prefix)
        .and_then(|rest| rest.strip_prefix('['))
        .and_then(|rest| rest.strip_suffix(']'))
        .map(|x| x.to_string())
}

impl SubspaceSet {
    /// Assemble directly from slots — the manifest-free path the engine
    /// golden tests and allocation benches use.
    pub fn from_slots(slots: Vec<MatrixSlot>, kind: ProjectorKind, c: f64) -> Self {
        assert!(!slots.is_empty(), "a SubspaceSet needs at least one slot");
        Self::assemble(slots, kind, c)
    }

    fn assemble(slots: Vec<MatrixSlot>, kind: ProjectorKind, c: f64) -> Self {
        let lift_keys = slots.iter().map(|s| format!("lift_b_norm[{}]", s.name)).collect();
        let rank_keys = slots.iter().map(|s| format!("rank[{}]", s.name)).collect();
        let mse_keys = slots.iter().map(|s| format!("mse_ratio[{}]", s.name)).collect();
        let lift_residuals = vec![0.0; slots.len()];
        SubspaceSet {
            slots,
            kind,
            c,
            outer_iterations: 0,
            track_refresh: 0,
            track_age: 0,
            lift_residuals,
            lift_keys,
            rank_keys,
            mse_keys,
            lift_scratch: crate::model::MutManyScratch::new(),
        }
    }

    /// Build from a manifest that has `bs[...]`/`vs[...]` inputs (the
    /// grad-style artifacts).
    pub fn from_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "bs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("B slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let db_output = manifest
                .outputs
                .iter()
                .position(|s| s.name == format!("out[1][{name}]"))
                .unwrap_or(usize::MAX);
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                r_max: r,
                b_input: spec.index,
                v_input,
                db_output,
                param_pos,
                b: Arc::new(vec![0.0; m * r]),
                v: Arc::new(vec![0.0; n * r]),
                adam: Adam::new(m * r, adam_cfg),
                frame: None,
                stage_b: None,
                stage_v: None,
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no bs[...] inputs", manifest.name);
        }
        Ok(Self::assemble(slots, kind, c))
    }

    /// Build for ZO artifacts: `zs[...]`/`vs[...]` inputs, no B input
    /// and no dB output (the estimator is formed in Rust).
    pub fn from_zo_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "zs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("Z slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                r_max: r,
                b_input: spec.index, // the Z slot doubles as the "B" input
                v_input,
                db_output: usize::MAX,
                param_pos,
                b: Arc::new(vec![0.0; m * r]),
                v: Arc::new(vec![0.0; n * r]),
                adam: Adam::new(m * r, adam_cfg),
                frame: None,
                stage_b: None,
                stage_v: None,
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no zs[...] inputs", manifest.name);
        }
        Ok(Self::assemble(slots, kind, c))
    }

    /// Enable warm-started subspace tracking: tracked refreshes with a
    /// full Haar redraw every `refresh_every`-th resample (0 disables;
    /// 1 degenerates to the classic fresh-draw trajectory bit for
    /// bit). Only meaningful for [`ProjectorKind::Stiefel`]; other
    /// laws keep drawing fresh regardless.
    pub fn set_tracking(&mut self, refresh_every: u64) {
        self.track_refresh = refresh_every;
    }

    fn tracking_active(&self) -> bool {
        self.track_refresh > 0 && self.kind == ProjectorKind::Stiefel
    }

    /// Resample every V (Algorithm 1 line 3): B ← 0, fresh (or
    /// warm-started) V, Adam moments reset (they live in the old
    /// subspace's coordinates).
    ///
    /// Draws fan out across the kernel pool via
    /// [`crate::projection::sample_batch`] — or, with tracking on
    /// ([`Self::set_tracking`]), via
    /// [`crate::projection::track_batch`], which refreshes the stored
    /// per-slot frames instead of re-drawing them. Either way one
    /// child stream is forked per slot (in slot order), so the result
    /// depends only on `rng` — not on the thread count.
    pub fn resample(&mut self, rng: &mut Rng) {
        let _span = crate::obs::span("engine", "resample");
        let dims: Vec<(usize, usize)> = self.slots.iter().map(|s| (s.n, s.r)).collect();
        let vs = if self.tracking_active() {
            let full = self.track_age % self.track_refresh == 0;
            self.track_age += 1;
            let mut frames: Vec<Option<Mat>> =
                self.slots.iter_mut().map(|s| s.frame.take()).collect();
            let vs = track_batch(&dims, self.c, &mut frames, full, rng);
            for (slot, frame) in self.slots.iter_mut().zip(frames) {
                slot.frame = frame;
            }
            vs
        } else {
            sample_batch(self.kind, &dims, self.c, None, rng)
        };
        for (slot, v) in self.slots.iter_mut().zip(vs) {
            for (dst, src) in Arc::make_mut(&mut slot.v).iter_mut().zip(&v.data) {
                *dst = *src as f32;
            }
            Arc::make_mut(&mut slot.b).iter_mut().for_each(|x| *x = 0.0);
            slot.adam.reset();
            slot.refresh_stage_v();
            slot.refresh_stage_b();
        }
        self.outer_iterations += 1;
    }

    /// Lift Θ ← Θ + B·Vᵀ into the store and zero B (Algorithm 1 line 8).
    ///
    /// The per-matrix lifts are independent (disjoint Θ tensors), so
    /// they fan out across the kernel pool — one task per slot, each
    /// running the serial GEMM body so the parallelism stays one level
    /// deep and the bytes match a serial pass exactly.
    pub fn lift(&mut self, store: &mut ParamStore) -> Result<()> {
        let _span = crate::obs::span("engine", "lift");
        let pool = kernel::global();
        if pool.threads() == 1 {
            // inline serial path: no boxed tasks, no view staging — the
            // zero-allocation contract's route (tests/engine_alloc.rs)
            for slot in &self.slots {
                let theta = store.f32_mut(slot.param_pos)?;
                kernel::serial::gemm_nt(
                    1.0f32,
                    slot.b.as_slice(),
                    slot.v.as_slice(),
                    theta,
                    slot.m,
                    slot.n,
                    slot.r,
                );
            }
        } else {
            let positions: Vec<usize> = self.slots.iter().map(|s| s.param_pos).collect();
            let slots = &self.slots;
            store.f32_mut_many_with(
                &positions,
                &mut self.lift_scratch,
                |thetas: &mut Vec<&mut [f32]>| {
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    for (slot, theta) in slots.iter().zip(thetas.drain(..)) {
                        let (m, n, r) = (slot.m, slot.n, slot.r);
                        let (b, v) = (slot.b.as_slice(), slot.v.as_slice());
                        tasks.push(Box::new(move || {
                            kernel::serial::gemm_nt(1.0f32, b, v, theta, m, n, r)
                        }));
                    }
                    pool.run(tasks);
                },
            )?;
        }
        // per-layer lift residual ‖B‖_F — how much subspace motion each
        // outer iteration folded into Θ. Always computed (one O(m·r)
        // pass, trivial next to the O(m·n·r) lift): the rank controller
        // reads the normalized form from `lift_residuals()`, and with
        // obs on it is also recorded under the precomputed
        // `lift_b_norm[<layer>]` key (no per-lift `format!`).
        let metrics_on = crate::obs::metrics::enabled();
        for (i, slot) in self.slots.iter().enumerate() {
            let norm = slot.b.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
            self.lift_residuals[i] = norm / ((slot.m * slot.r) as f64).sqrt();
            if metrics_on {
                crate::obs::metrics::record_value(&self.lift_keys[i], norm);
            }
        }
        for slot in &mut self.slots {
            Arc::make_mut(&mut slot.b).iter_mut().for_each(|x| *x = 0.0);
        }
        Ok(())
    }

    /// Per-slot RMS lift residuals ‖B‖_F/√(m·r) from the most recent
    /// [`Self::lift`] — rank-comparable, so the controller can apply
    /// one threshold across slots of different shapes.
    pub fn lift_residuals(&self) -> &[f64] {
        &self.lift_residuals
    }

    /// Current active ranks, slot order.
    pub fn ranks(&self) -> Vec<usize> {
        self.slots.iter().map(|s| s.r).collect()
    }

    /// Precomputed `rank[<name>]` metric key for slot `i`.
    pub fn rank_key(&self, i: usize) -> &str {
        &self.rank_keys[i]
    }

    /// Precomputed `mse_ratio[<name>]` metric key for slot `i` — the
    /// quality probe's variance-vs-bound gauge series.
    pub fn mse_key(&self, i: usize) -> &str {
        &self.mse_keys[i]
    }

    /// Re-layout slot `i` to active rank `new_r` < r, in place: B and V
    /// compact to `[m, new_r]`/`[n, new_r]` (tail capacity released, so
    /// the drop is visible to the measured memory ledger), the Adam
    /// moments compact with them, the tracked frame keeps its leading
    /// `new_r` columns (still orthonormal), and the artifact staging
    /// pads are (re)built at the manifest shape.
    ///
    /// Callers shrink only at a lazy-update boundary — after
    /// [`Self::lift`] (B = 0) and before [`Self::resample`] (V redrawn
    /// at the new rank, Adam reset) — so no live trajectory state needs
    /// numerical rescaling; this is purely a re-layout.
    pub fn shrink_slot_rank(&mut self, i: usize, new_r: usize) -> Result<()> {
        let slot = self.slots.get_mut(i).with_context(|| format!("no slot {i}"))?;
        if new_r == slot.r {
            return Ok(());
        }
        if new_r == 0 || new_r > slot.r {
            bail!(
                "slot {} rank can only shrink: active {}, requested {new_r}",
                slot.name,
                slot.r
            );
        }
        let old_r = slot.r;
        compact_cols(&mut slot.b, slot.m, old_r, new_r);
        compact_cols(&mut slot.v, slot.n, old_r, new_r);
        slot.adam.shrink_cols(slot.m, old_r, new_r);
        if let Some(frame) = &mut slot.frame {
            // leading columns of an orthonormal frame stay orthonormal
            let mut f = Mat::zeros(slot.n, new_r);
            for row in 0..slot.n {
                f.data[row * new_r..(row + 1) * new_r]
                    .copy_from_slice(&frame.data[row * old_r..row * old_r + new_r]);
            }
            *frame = f;
        }
        slot.r = new_r;
        if slot.stage_b.is_none() {
            slot.stage_b = Some(Arc::new(vec![0.0; slot.m * slot.r_max]));
            slot.stage_v = Some(Arc::new(vec![0.0; slot.n * slot.r_max]));
        } else {
            // pads carry stale columns from the wider layout — zero the
            // now-inactive region before the compact copy-back
            for (pad, rows) in [(&mut slot.stage_b, slot.m), (&mut slot.stage_v, slot.n)] {
                let dst = Arc::make_mut(pad.as_mut().expect("pad exists"));
                for row in 0..rows {
                    dst[row * slot.r_max + new_r..(row + 1) * slot.r_max].fill(0.0);
                }
            }
        }
        slot.refresh_stage_b();
        slot.refresh_stage_v();
        Ok(())
    }

    /// Refresh the artifact staging pads from the compact buffers.
    /// Trainers call this once per step before staging inputs; it is a
    /// no-op until a slot has actually shrunk.
    pub fn refresh_stage(&mut self) {
        for slot in &mut self.slots {
            slot.refresh_stage_b();
        }
    }

    /// One Adam step per slot's B, fanned out across the kernel pool.
    /// Slots are independent, so parallel equals serial bitwise.
    /// Generic over the gradient container (`Vec<f32>`, `&[f32]`, …) so
    /// callers holding borrowed artifact outputs never have to copy.
    pub fn adam_step_all<G: AsRef<[f32]> + Sync>(&mut self, grads: &[G], lr: f32) {
        assert_eq!(grads.len(), self.slots.len(), "one gradient per slot");
        let pool = kernel::global();
        if pool.threads() == 1 {
            // inline serial path: boxing the tasks would allocate, and
            // this runs once per IPA step inside the zero-allocation
            // contract (tests/engine_alloc.rs)
            for (slot, g) in self.slots.iter_mut().zip(grads) {
                slot.adam.step(Arc::make_mut(&mut slot.b), g.as_ref(), lr);
            }
            return;
        }
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (slot, g) in self.slots.iter_mut().zip(grads) {
            tasks.push(Box::new(move || {
                slot.adam.step(Arc::make_mut(&mut slot.b), g.as_ref(), lr)
            }));
        }
        pool.run(tasks);
    }

    pub fn outer_iterations(&self) -> u64 {
        self.outer_iterations
    }

    /// Σ m·r — total subspace parameter count (the memory story).
    pub fn b_elements(&self) -> usize {
        self.slots.iter().map(|s| s.m * s.r).sum()
    }

    /// Bytes of optimizer state held by the subspace Adam instances.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.adam.state_bytes()).sum()
    }
}

/// Checkpointing: per slot the live B and V matrices (at the *active*
/// rank) plus the nested Adam moments (`adam[<name>].{m,v,t}` — `t` is
/// the per-slot inner-step counter), the per-slot active ranks, the
/// outer-iteration and tracked-refresh counters, and — when tracking
/// has drawn them — the f64 unit frames. Restoring mid-outer-iteration
/// continues in the *same* subspace V with the same optimizer momentum
/// and the same warm-start frame at the same point of the refresh
/// schedule, which is what makes a resumed tracked run reproduce the
/// uninterrupted trajectory bit for bit (frames round-trip at full f64
/// precision — reconstructing them from the stored f32 V would lose
/// the low bits and fork the stream of tracked updates).
impl crate::ckpt::Checkpointable for SubspaceSet {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_u64s("outer_iterations", &[self.outer_iterations]);
        sd.put_u64s("track_age", &[self.track_age]);
        let ranks: Vec<u64> = self.slots.iter().map(|s| s.r as u64).collect();
        sd.put_u64s("ranks", &ranks);
        for slot in &self.slots {
            sd.put_tensor(
                format!("b[{}]", slot.name),
                crate::runtime::HostTensor::f32_shared(vec![slot.m, slot.r], slot.b.clone()),
            );
            sd.put_tensor(
                format!("v[{}]", slot.name),
                crate::runtime::HostTensor::f32_shared(vec![slot.n, slot.r], slot.v.clone()),
            );
            sd.merge_prefixed(&format!("adam[{}].", slot.name), slot.adam.state_dict());
            if let Some(frame) = &slot.frame {
                sd.put_f64_bits(format!("frame[{}]", slot.name), &frame.data);
            }
        }
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> Result<()> {
        // 3 scalars/rank vectors + per slot: b, v, adam.{m,v,t}, and a
        // frame per slot iff the run had drawn tracked frames
        let base = 3 + 5 * self.slots.len();
        let has_frames = if sd.len() == base {
            false
        } else if sd.len() == base + self.slots.len() {
            true
        } else {
            bail!(
                "subspace checkpoint has {} tensors, expected {base} (untracked) or {}",
                sd.len(),
                base + self.slots.len()
            );
        };
        let outer = sd.u64("outer_iterations")?;
        let age = sd.u64("track_age")?;
        let ranks = sd.u64s("ranks")?;
        if ranks.len() != self.slots.len() {
            bail!("subspace checkpoint has {} ranks for {} slots", ranks.len(), self.slots.len());
        }
        // validate every slot's shapes/dtypes against the *saved* rank,
        // staging the payloads by Arc share (no per-slot copy — the
        // live buffers unshare lazily on first mutation) …
        let mut staged_b: Vec<Arc<Vec<f32>>> = Vec::with_capacity(self.slots.len());
        let mut staged_v: Vec<Arc<Vec<f32>>> = Vec::with_capacity(self.slots.len());
        let mut staged_frames: Vec<Option<Mat>> = Vec::with_capacity(self.slots.len());
        for (slot, &rank) in self.slots.iter().zip(&ranks) {
            let rk = rank as usize;
            if rk == 0 || rk > slot.r_max {
                bail!(
                    "subspace checkpoint rank {rk} for slot {} is outside 1..={}",
                    slot.name,
                    slot.r_max
                );
            }
            let b_t = sd.tensor(&format!("b[{}]", slot.name))?;
            if b_t.shape() != [slot.m, rk] {
                bail!(
                    "subspace checkpoint b[{}] has shape {:?}, expected [{}, {rk}]",
                    slot.name,
                    b_t.shape(),
                    slot.m,
                );
            }
            staged_b.push(b_t.f32_arc()?);
            let v_t = sd.tensor(&format!("v[{}]", slot.name))?;
            if v_t.shape() != [slot.n, rk] {
                bail!(
                    "subspace checkpoint v[{}] has shape {:?}, expected [{}, {rk}]",
                    slot.name,
                    v_t.shape(),
                    slot.n,
                );
            }
            staged_v.push(v_t.f32_arc()?);
            if has_frames {
                let data = sd.f64_bits(&format!("frame[{}]", slot.name))?;
                if data.len() != slot.n * rk {
                    bail!(
                        "subspace checkpoint frame[{}] has {} elements, expected {}",
                        slot.name,
                        data.len(),
                        slot.n * rk
                    );
                }
                staged_frames.push(Some(Mat { rows: slot.n, cols: rk, data }));
            } else {
                staged_frames.push(None);
            }
        }
        // … then apply, re-laying each slot out at its saved rank
        for (((slot, b), v), (frame, &rank)) in self
            .slots
            .iter_mut()
            .zip(staged_b)
            .zip(staged_v)
            .zip(staged_frames.into_iter().zip(&ranks))
        {
            let rk = rank as usize;
            slot.r = rk;
            slot.b = b;
            slot.v = v;
            slot.frame = frame;
            if rk < slot.r_max {
                // fresh zeroed pads (not a hot path): any stale columns
                // from a previous layout must not leak into staging
                slot.stage_b = Some(Arc::new(vec![0.0; slot.m * slot.r_max]));
                slot.stage_v = Some(Arc::new(vec![0.0; slot.n * slot.r_max]));
            }
            slot.refresh_stage_b();
            slot.refresh_stage_v();
            slot.adam.resize(slot.m * rk);
            slot.adam
                .load_state(&sd.extract_prefixed(&format!("adam[{}].", slot.name)))
                .with_context(|| format!("subspace slot {}", slot.name))?;
        }
        self.outer_iterations = outer;
        self.track_age = age;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostTensor, TensorSpec};

    const TOY_MANIFEST: &str = "\
artifact = toy_grad
num_inputs = 5
num_outputs = 2
input 0 params[embed] f32 8x4
input 1 params[w0] f32 4x4
input 2 bs[w0] f32 4x2
input 3 vs[w0] f32 4x2
input 4 tokens i32 2x3
output 0 out[0] f32 scalar
output 1 out[1][w0] f32 4x2
";

    fn toy_set() -> SubspaceSet {
        let manifest = ArtifactManifest::parse(TOY_MANIFEST).unwrap();
        let specs: Vec<TensorSpec> = manifest
            .inputs
            .iter()
            .take(2)
            .cloned()
            .collect();
        let tensors = vec![
            HostTensor::f32(vec![8, 4], vec![0.0; 32]),
            HostTensor::f32(vec![4, 4], vec![0.0; 16]),
        ];
        let store = ParamStore::for_test(specs, tensors);
        SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0, AdamConfig::default())
            .unwrap()
    }

    #[test]
    fn checkpoint_roundtrip_restores_b_v_and_moments_bitwise() {
        let mut src = toy_set();
        let mut rng = Rng::new(5);
        src.resample(&mut rng);
        // advance the slot optimizer so moments and t are non-trivial
        for k in 0..3 {
            let g: Vec<f32> = (0..8).map(|i| (k * 8 + i) as f32 * 0.1 - 0.3).collect();
            let slot = &mut src.slots[0];
            slot.adam.step(std::sync::Arc::make_mut(&mut slot.b), &g, 1e-2);
        }
        let sd = src.state_dict();

        let mut dst = toy_set();
        dst.load_state(&sd).unwrap();
        assert_eq!(dst.outer_iterations(), 1);
        for (a, b) in src.slots.iter().zip(&dst.slots) {
            assert_eq!(a.adam.steps_taken(), b.adam.steps_taken());
            for (x, y) in a.b.iter().zip(&b.b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.v.iter().zip(&b.v) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a truncated dict is rejected
        let partial = sd.extract_prefixed("");
        assert_eq!(partial.len(), sd.len());
        let mut missing = crate::ckpt::StateDict::new();
        missing.put_u64s("outer_iterations", &[1]);
        assert!(dst.load_state(&missing).is_err());
    }

    const TRIPLE_MANIFEST: &str = "\
artifact = toy3_grad
num_inputs = 10
num_outputs = 4
input 0 params[w0] f32 40x24
input 1 params[w1] f32 24x24
input 2 params[w2] f32 48x16
input 3 bs[w0] f32 40x3
input 4 vs[w0] f32 24x3
input 5 bs[w1] f32 24x2
input 6 vs[w1] f32 24x2
input 7 bs[w2] f32 48x4
input 8 vs[w2] f32 16x4
input 9 tokens i32 2x3
output 0 out[0] f32 scalar
output 1 out[1][w0] f32 40x3
output 2 out[1][w1] f32 24x2
output 3 out[1][w2] f32 48x4
";

    fn triple_store() -> ParamStore {
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let specs: Vec<TensorSpec> = manifest.inputs.iter().take(3).cloned().collect();
        let tensors = specs
            .iter()
            .map(|s| {
                let len: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..len).map(|i| (i as f32) * 1e-3 - 0.2).collect(),
                )
            })
            .collect();
        ParamStore::for_test(specs, tensors)
    }

    /// Collect every file under `dir` as (relative path, bytes).
    fn dir_bytes(dir: &std::path::Path) -> std::collections::BTreeMap<String, Vec<u8>> {
        fn walk(
            root: &std::path::Path,
            dir: &std::path::Path,
            out: &mut std::collections::BTreeMap<String, Vec<u8>>,
        ) {
            for entry in std::fs::read_dir(dir).unwrap() {
                let path = entry.unwrap().path();
                if path.is_dir() {
                    walk(root, &path, out);
                } else {
                    let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                    out.insert(rel, std::fs::read(&path).unwrap());
                }
            }
        }
        let mut out = std::collections::BTreeMap::new();
        walk(dir, dir, &mut out);
        out
    }

    /// Drive the full slot fan-out (resample → per-slot Adam steps →
    /// lift) at a given pool size, returning the final parameter bits
    /// and the committed checkpoint bytes.
    fn run_slot_fanout(threads: usize) -> (Vec<u32>, std::collections::BTreeMap<String, Vec<u8>>) {
        crate::kernel::set_global_threads(threads);
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let mut store = triple_store();
        let mut set = SubspaceSet::from_manifest(
            &manifest,
            &store,
            ProjectorKind::Stiefel,
            1.0,
            AdamConfig::default(),
        )
        .unwrap();
        let mut rng = Rng::new(4242);
        for outer in 0..2u64 {
            set.resample(&mut rng);
            for step in 0..3u64 {
                let grads: Vec<Vec<f32>> = set
                    .slots
                    .iter()
                    .enumerate()
                    .map(|(si, s)| {
                        (0..s.m * s.r)
                            .map(|i| (((outer * 100 + step * 31 + si as u64 * 7 + i as u64) as f32)
                                * 0.01)
                                .sin())
                            .collect()
                    })
                    .collect();
                set.adam_step_all(&grads, 1e-2);
            }
            set.lift(&mut store).unwrap();
        }
        let bits: Vec<u32> = (0..store.len())
            .flat_map(|i| store.f32(i).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>())
            .collect();
        // PID-unique path so concurrent test binaries on one machine
        // cannot race each other's remove/save/read cycle
        let dir = std::env::temp_dir()
            .join(format!("lowrank_sge_slot_fanout_p{}_t{threads}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        crate::ckpt::save_checkpoint(
            &dir,
            1,
            &[],
            &[("params", store.state_dict()), ("subspace", set.state_dict())],
            0,
        )
        .unwrap();
        let bytes = dir_bytes(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        (bits, bytes)
    }

    #[test]
    fn slot_fanout_is_thread_count_invariant() {
        // Satellite: a 3-matrix artifact stepped with threads = 1 and
        // threads = 4 must produce identical ParamStore bytes and
        // identical checkpoint shards.
        let _guard = crate::kernel::pool::global_test_guard();
        let prev_threads = crate::kernel::global_threads();
        let (bits_serial, ckpt_serial) = run_slot_fanout(1);
        let (bits_par, ckpt_par) = run_slot_fanout(4);
        // restore so the LOWRANK_THREADS-driven CI legs keep their
        // configured pool size for the rest of the suite
        crate::kernel::set_global_threads(prev_threads);
        assert!(!bits_serial.is_empty());
        assert_eq!(bits_serial, bits_par, "ParamStore bytes diverged across thread counts");
        assert_eq!(
            ckpt_serial.keys().collect::<Vec<_>>(),
            ckpt_par.keys().collect::<Vec<_>>()
        );
        for (name, bytes) in &ckpt_serial {
            assert_eq!(bytes, &ckpt_par[name], "checkpoint shard {name} diverged");
        }
        assert!(ckpt_serial.keys().any(|k| k.contains("MANIFEST")));
    }

    #[test]
    fn shrink_relayouts_b_v_adam_and_staging_pads() {
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let mut store = triple_store();
        let mut set = SubspaceSet::from_manifest(
            &manifest,
            &store,
            ProjectorKind::Stiefel,
            1.0,
            AdamConfig::default(),
        )
        .unwrap();
        let mut rng = Rng::new(9);
        set.resample(&mut rng);
        let bytes_before = set.optimizer_state_bytes();
        // boundary discipline: lift (B = 0), shrink, resample
        set.lift(&mut store).unwrap();
        set.shrink_slot_rank(0, 2).unwrap();
        set.resample(&mut rng);
        let s = &set.slots[0];
        assert_eq!((s.r, s.r_max), (2, 3));
        assert_eq!(s.b.len(), s.m * 2);
        assert_eq!(s.v.len(), s.n * 2);
        assert!(set.optimizer_state_bytes() < bytes_before);
        // artifact staging stays at the manifest shape, zero-padded
        let (shape_b, pad_b) = set.slots[0].staged_b();
        let (shape_v, pad_v) = set.slots[0].staged_v();
        assert_eq!(shape_b, vec![set.slots[0].m, 3]);
        assert_eq!(shape_v, vec![set.slots[0].n, 3]);
        for row in 0..set.slots[0].m {
            assert_eq!(pad_b[row * 3 + 2], 0.0, "pad column must stay zero");
        }
        for row in 0..set.slots[0].n {
            assert_eq!(pad_v[row * 3 + 2], 0.0, "pad column must stay zero");
            assert_eq!(pad_v[row * 3], set.slots[0].v[row * 2]);
            assert_eq!(pad_v[row * 3 + 1], set.slots[0].v[row * 2 + 1]);
        }
        // unshrunk slots stage the compact buffer directly
        let (shape1, _) = set.slots[1].staged_b();
        assert_eq!(shape1, vec![set.slots[1].m, set.slots[1].r]);
        // growth and rank 0 are rejected
        assert!(set.shrink_slot_rank(0, 3).is_err());
        assert!(set.shrink_slot_rank(0, 0).is_err());
        // the lift still works at the compact rank
        let grads: Vec<Vec<f32>> =
            set.slots.iter().map(|s| vec![0.01; s.m * s.r]).collect();
        set.adam_step_all(&grads, 1e-2);
        set.lift(&mut store).unwrap();
        assert!(set.lift_residuals()[0] > 0.0);
    }

    #[test]
    fn tracked_checkpoint_roundtrips_frames_and_ranks_bitwise() {
        fn make(manifest: &ArtifactManifest, store: &ParamStore) -> SubspaceSet {
            let mut set = SubspaceSet::from_manifest(
                manifest,
                store,
                ProjectorKind::Stiefel,
                1.0,
                AdamConfig::default(),
            )
            .unwrap();
            set.set_tracking(3);
            set
        }
        let manifest = ArtifactManifest::parse(TRIPLE_MANIFEST).unwrap();
        let mut store = triple_store();
        let mut src = make(&manifest, &store);
        let mut rng = Rng::new(77);
        src.resample(&mut rng); // full draw (age 0)
        src.resample(&mut rng); // tracked
        src.lift(&mut store).unwrap();
        src.shrink_slot_rank(2, 2).unwrap();
        src.resample(&mut rng); // tracked, slot 2 now rank 2
        let sd = src.state_dict();
        // frames present → one extra tensor per slot
        assert_eq!(sd.len(), 3 + 6 * src.slots.len());

        let mut dst = make(&manifest, &store);
        dst.load_state(&sd).unwrap();
        assert_eq!(dst.ranks(), src.ranks());
        for (a, b) in src.slots.iter().zip(&dst.slots) {
            let (fa, fb) = (a.frame.as_ref().unwrap(), b.frame.as_ref().unwrap());
            assert_eq!(fa.data.len(), fb.data.len());
            for (x, y) in fa.data.iter().zip(&fb.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "frame bits diverged");
            }
        }
        // the decisive property: both continue identically — the next
        // tracked refresh depends on the restored frame bits and age
        let mut rng_a = Rng::new(5150);
        let mut rng_b = Rng::new(5150);
        src.resample(&mut rng_a);
        dst.resample(&mut rng_b);
        for (a, b) in src.slots.iter().zip(&dst.slots) {
            for (x, y) in a.v.iter().zip(b.v.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "post-restore V diverged");
            }
        }
    }

    #[test]
    fn bracket_name_parses() {
        assert_eq!(bracket_name("bs[layer0.w1]", "bs").as_deref(), Some("layer0.w1"));
        assert_eq!(bracket_name("vs[x]", "vs").as_deref(), Some("x"));
        assert_eq!(bracket_name("tokens", "bs"), None);
        assert_eq!(bracket_name("bs[unclosed", "bs"), None);
        // params[...] must not match the bs prefix
        assert_eq!(bracket_name("params[embed]", "bs"), None);
    }
}
