//! Per-matrix subspace state: the (B, V) pair of Algorithm 1 plus its
//! Adam moments, wired to the artifact input/output slots by name.
//!
//! The manifest naming convention (aot.py) is the contract:
//!   inputs  `params[<name>]`, `bs[<name>]`, `vs[<name>]`, `tokens`, …
//!   outputs `out[0]` (loss), `out[1][<name>]` (dB), `out[2][<name>]`
//!   (full-rank gradients for embeddings/norms — LM artifacts only).

use anyhow::{bail, Context, Result};

use crate::model::{lift_into, ParamStore};
use crate::optim::{Adam, AdamConfig};
use crate::projection::{build_sampler, ProjectorKind};
use crate::rng::Rng;
use crate::runtime::ArtifactManifest;

/// One reparameterized matrix W (m×n) with its auxiliary B (m×r) and
/// projector V (n×r).
pub struct MatrixSlot {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub r: usize,
    /// Artifact input slot of B (usize::MAX if the artifact has no B
    /// input, e.g. the ZO artifacts where B ≡ ±σZ).
    pub b_input: usize,
    /// Artifact input slot of V.
    pub v_input: usize,
    /// Artifact output slot of dB (usize::MAX for ZO artifacts).
    pub db_output: usize,
    /// Position of W in the [`ParamStore`].
    pub param_pos: usize,
    pub b: Vec<f32>,
    pub v: Vec<f32>,
    pub adam: Adam,
}

/// A full-rank trainable (embedding / norm) with its gradient output.
pub struct FullSlot {
    pub name: String,
    pub param_pos: usize,
    pub dout: usize,
    pub adam: Adam,
}

/// All subspace state for one artifact.
pub struct SubspaceSet {
    pub slots: Vec<MatrixSlot>,
    pub kind: ProjectorKind,
    pub c: f64,
    outer_iterations: u64,
}

fn bracket_name(s: &str, prefix: &str) -> Option<String> {
    // "bs[layer0.w1]" with prefix "bs" → "layer0.w1"
    s.strip_prefix(prefix)
        .and_then(|rest| rest.strip_prefix('['))
        .and_then(|rest| rest.strip_suffix(']'))
        .map(|x| x.to_string())
}

impl SubspaceSet {
    /// Build from a manifest that has `bs[...]`/`vs[...]` inputs (the
    /// grad-style artifacts).
    pub fn from_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "bs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("B slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let db_output = manifest
                .outputs
                .iter()
                .position(|s| s.name == format!("out[1][{name}]"))
                .unwrap_or(usize::MAX);
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index,
                v_input,
                db_output,
                param_pos,
                b: vec![0.0; m * r],
                v: vec![0.0; n * r],
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no bs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet { slots, kind, c, outer_iterations: 0 })
    }

    /// Build for ZO artifacts: `zs[...]`/`vs[...]` inputs, no B input
    /// and no dB output (the estimator is formed in Rust).
    pub fn from_zo_manifest(
        manifest: &ArtifactManifest,
        store: &ParamStore,
        kind: ProjectorKind,
        c: f64,
        adam_cfg: AdamConfig,
    ) -> Result<Self> {
        let mut slots = Vec::new();
        for spec in &manifest.inputs {
            let Some(name) = bracket_name(&spec.name, "zs") else { continue };
            let (m, r) = match spec.shape.as_slice() {
                [m, r] => (*m, *r),
                other => bail!("Z slot {name} has shape {other:?}"),
            };
            let v_input = manifest
                .inputs
                .iter()
                .position(|s| s.name == format!("vs[{name}]"))
                .with_context(|| format!("no vs[{name}] input"))?;
            let n = manifest.inputs[v_input].shape[0];
            let param_pos = store
                .position(&format!("[{name}]"))
                .with_context(|| format!("param {name} not in store"))?;
            slots.push(MatrixSlot {
                name,
                m,
                n,
                r,
                b_input: spec.index, // the Z slot doubles as the "B" input
                v_input,
                db_output: usize::MAX,
                param_pos,
                b: vec![0.0; m * r],
                v: vec![0.0; n * r],
                adam: Adam::new(m * r, adam_cfg),
            });
        }
        if slots.is_empty() {
            bail!("manifest {} has no zs[...] inputs", manifest.name);
        }
        Ok(SubspaceSet { slots, kind, c, outer_iterations: 0 })
    }

    /// Resample every V (Algorithm 1 line 3): B ← 0, fresh V, Adam
    /// moments reset (they live in the old subspace's coordinates).
    pub fn resample(&mut self, rng: &mut Rng) {
        for slot in &mut self.slots {
            let mut sampler = build_sampler(self.kind, slot.n, slot.r, self.c, None);
            let v = sampler.sample(rng);
            for (dst, src) in slot.v.iter_mut().zip(&v.data) {
                *dst = *src as f32;
            }
            slot.b.iter_mut().for_each(|x| *x = 0.0);
            slot.adam.reset();
        }
        self.outer_iterations += 1;
    }

    /// Lift Θ ← Θ + B·Vᵀ into the store and zero B (Algorithm 1 line 8).
    pub fn lift(&mut self, store: &mut ParamStore) -> Result<()> {
        for slot in &mut self.slots {
            let theta = store.f32_mut(slot.param_pos)?;
            lift_into(theta, &slot.b, &slot.v, slot.m, slot.n, slot.r);
            slot.b.iter_mut().for_each(|x| *x = 0.0);
        }
        Ok(())
    }

    pub fn outer_iterations(&self) -> u64 {
        self.outer_iterations
    }

    /// Σ m·r — total subspace parameter count (the memory story).
    pub fn b_elements(&self) -> usize {
        self.slots.iter().map(|s| s.m * s.r).sum()
    }

    /// Bytes of optimizer state held by the subspace Adam instances.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.adam.state_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bracket_name_parses() {
        assert_eq!(bracket_name("bs[layer0.w1]", "bs").as_deref(), Some("layer0.w1"));
        assert_eq!(bracket_name("vs[x]", "vs").as_deref(), Some("x"));
        assert_eq!(bracket_name("tokens", "bs"), None);
        assert_eq!(bracket_name("bs[unclosed", "bs"), None);
        // params[...] must not match the bs prefix
        assert_eq!(bracket_name("params[embed]", "bs"), None);
    }
}
