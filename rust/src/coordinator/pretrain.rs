//! LowRank-IPA pretraining (paper §6.2.2, Figures 7–9).
//!
//! The trainer realizes Algorithm 1 over the `lm_grad_<scale>` artifact:
//! every K steps it lifts Θ ← Θ + B·Vᵀ and resamples V from the
//! configured projector law (Stiefel vs Gaussian is the Figures 7–9
//! contrast). With `--track-refresh T` the Stiefel resample is
//! warm-started ([`crate::projection::tracking`]): the previous frame is
//! refreshed in place, with a full Haar redraw every T-th resample; with
//! `--rank-adapt` an online [`RankController`] watches the all-reduced
//! lift residuals at each boundary and shrinks a slot's rank in place
//! (B, V, Adam moments, engine scratch, and the gradient wire all drop
//! to the new m·r footprint — the artifact keeps its compiled [·, r_max]
//! shapes via zero-padded staging). Each inner step executes the
//! artifact once per DDP worker
//! shard, all-reduces the gradients through the configured
//! [`Collective`] backend (in-process pairing tree, or the
//! [`crate::comm`] ring/tree collectives when this trainer is one rank
//! of a `lowrank-sge launch` world — same combine order, bitwise; the
//! per-slot collectives run through the slot pipeline of
//! [`Collective::allreduce_mean_slots`], overlapping each slot's chunk
//! reduce with the next slot's ring exchange, and optionally compress
//! the wire to bf16 via `--comm-dtype`),
//! clips, and hands the reduced gradients to the shared pipeline —
//! [`crate::estimator::engine::GradEstimator`] — which fans the
//! subspace-B and full-rank (embeddings/norms) Adam steps out across
//! the kernel pool. Input staging is zero-copy: parameters, (B, V) and
//! the shard tokens are spliced by `Arc` bump.
//!
//! Checkpoints are leader-only (enforced — see
//! [`super::ddp::LEADER_RANK`]) and fully asynchronous: `save_state`
//! snapshots the `Arc`-backed state dicts and hands the write to the
//! [`crate::ckpt::AsyncCheckpointer`], so the step loop never blocks on
//! IO; write errors surface at the next save or at shutdown.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::ddp::{BatchProducer, Collective};
use super::metrics::{MetricsLog, StepRecord};
use super::subspace::{FullSlot, SubspaceSet};
use crate::ckpt::{
    self, AsyncCheckpointer, Checkpointable, CkptOptions, LoadedCheckpoint, StateDict,
};
use crate::data::ZipfMarkovCorpus;
use crate::estimator::engine::{GradEstimator, GradSignal, MethodShape};
use crate::model::ParamStore;
use crate::obs::monitor;
use crate::obs::quality::QualityProbe;
use crate::optim::{
    clip_global_norm, Adam, AdamConfig, CosineSchedule, LazyAction, LazyUpdateController,
    LrSchedule, RankAdaptConfig, RankController, RankDecision,
};
use crate::projection::ProjectorKind;
use crate::rng::Rng;
use crate::runtime::{HostTensor, LoadedArtifact, Runtime};

/// Pretraining configuration (paper §6.2.2, scaled to the proxy).
#[derive(Clone, Debug)]
pub struct PretrainConfig {
    /// Artifact scale: "s" | "m" | "l".
    pub scale: String,
    pub sampler: ProjectorKind,
    /// Weak-unbiasedness scale c (1.0 = strong).
    pub c: f64,
    /// Lazy-update interval K (paper: 200; proxy default 25).
    pub k_interval: u64,
    pub steps: u64,
    pub lr: f32,
    pub warmup: u64,
    /// Global-norm clip (paper: 1.0).
    pub clip: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Global DDP worker count (shards per step; global batch =
    /// workers × 8). In a multi-process `launch` run this is the total
    /// across all ranks and must divide evenly by the world size.
    pub workers: usize,
    /// Evaluate every this many steps (0 = never). Eval runs on a
    /// lifted copy, so it is exact at any step.
    pub eval_every: u64,
    pub eval_batches: usize,
    /// Kernel pool size for this run (`--threads`); > 0 resizes the
    /// process-global pool, 0 leaves it as it currently is (initially:
    /// `LOWRANK_THREADS` env, else available parallelism — or whatever
    /// a previous run in this process set). Results are bitwise
    /// identical at any value.
    pub threads: usize,
    /// Checkpoint/resume policy (default: disabled).
    pub ckpt: CkptOptions,
    /// Warm-started subspace tracking (Stiefel only): every resample
    /// refreshes the previous frame with a rank-1 tilt + Cholesky-QR
    /// instead of a fresh n×r Gaussian QR, redrawing a full Haar frame
    /// every this many resamples. 0 disables tracking (every resample
    /// is a fresh draw — the paper-exact schedule).
    pub track_refresh: u64,
    /// Online per-layer rank controller: watch the all-reduced lift
    /// residuals and shrink a slot's rank when the trend decays.
    /// `None` keeps every rank fixed at the manifest value.
    pub rank_adapt: Option<RankAdaptConfig>,
    /// Estimator-quality probe cadence (`--probe-every`): every this
    /// many steps one rotating slot gets a paired probe
    /// ([`crate::obs::quality`]); 0 disables the rotating probes (the
    /// lazy-update-boundary gauges still run whenever metrics are on).
    /// Probe directions come from a dedicated stream, so trained bytes
    /// are bitwise identical with probing on or off.
    pub probe_every: u64,
}

impl PretrainConfig {
    pub fn quick(scale: &str, sampler: ProjectorKind) -> Self {
        PretrainConfig {
            scale: scale.to_string(),
            sampler,
            c: 1.0,
            k_interval: 25,
            steps: 100,
            lr: 2e-3,
            warmup: 10,
            clip: 1.0,
            weight_decay: 0.05,
            seed: 2026,
            workers: 1,
            eval_every: 25,
            eval_batches: 2,
            threads: 0,
            ckpt: CkptOptions::default(),
            track_refresh: 8,
            rank_adapt: None,
            probe_every: 0,
        }
    }
}

/// Where each artifact input comes from.
enum Src {
    Param(usize),
    B(usize),
    V(usize),
    Tokens,
}

/// Extracted step-loop state: everything `run()` used to keep on its
/// stack between iterations — the lazy controller, LR schedule, the
/// background batch producers, the held-out eval sets, the step
/// cursor, and the metrics log. A scheduler ([`crate::serve`]) can
/// interleave [`PretrainTrainer::step_once`] calls across jobs; each
/// trainer retraces the exact operation sequence of an uninterrupted
/// [`PretrainTrainer::run`].
pub struct PretrainLoop {
    controller: LazyUpdateController,
    schedule: CosineSchedule,
    producer: BatchProducer,
    eval_sets: Vec<Vec<i32>>,
    log: MetricsLog,
    step: u64,
}

impl PretrainLoop {
    /// Next step index to run (`== cfg.steps` once exhausted).
    pub fn step(&self) -> u64 {
        self.step
    }
}

/// Result summary.
pub struct PretrainResult {
    pub log: MetricsLog,
    pub final_eval_loss: Option<f32>,
    pub params_elements: usize,
    pub b_elements: usize,
}

pub struct PretrainTrainer {
    cfg: PretrainConfig,
    grad_art: Arc<LoadedArtifact>,
    eval_art: Arc<LoadedArtifact>,
    store: ParamStore,
    /// The Algorithm-1 pipeline: subspace (B, V, Adam) state plus the
    /// full-rank embedding/norm channels.
    engine: GradEstimator,
    /// Gradient-averaging backend: in-process pairing tree, or one rank
    /// of a multi-process `launch` world over [`crate::comm`].
    collective: Collective,
    /// Background checkpoint writer (leader rank only ever submits).
    ckpt_writer: AsyncCheckpointer,
    input_map: Vec<Src>,
    rng: Rng,
    batch: usize,
    seq_len: usize,
    vocab: usize,
    /// Online per-layer rank controller (`--rank-adapt`).
    rank_ctl: Option<RankController>,
    /// Artifact output slot of each subspace dB, in slot order.
    db_outs: Vec<usize>,
    /// Artifact output slot of each full-rank gradient, in slot order.
    f_douts: Vec<usize>,
    /// Persistent dB/dΘ staging: `grad_stage[k][s]` is slot k's shard-s
    /// contribution, doubling as the all-reduce scratch (the reduced
    /// gradient lands in `[k][0]`). Reused across steps, so the
    /// execute→reduce path stops re-allocating full-gradient buffers.
    grad_stage: Vec<Vec<Vec<f32>>>,
    /// Estimator-quality telemetry: per-slot bias sentinels, the
    /// rotating `--probe-every` schedule, and the dedicated probe RNG
    /// (never the trainer stream — see [`crate::obs::quality`]).
    quality: QualityProbe,
}

impl PretrainTrainer {
    /// Single-process construction (the in-process DDP topology).
    pub fn new(rt: &mut Runtime, artifacts_dir: &Path, cfg: PretrainConfig) -> Result<Self> {
        Self::with_collective(rt, artifacts_dir, cfg, Collective::in_process())
    }

    /// Construct on an explicit collective backend. With
    /// `Collective::Comm`, `cfg.workers` is the *global* shard count:
    /// it must divide evenly across the world, and this rank runs the
    /// contiguous worker slice `[rank·(workers/world), …)` with the
    /// same per-worker RNG streams as the single-process run.
    pub fn with_collective(
        rt: &mut Runtime,
        artifacts_dir: &Path,
        cfg: PretrainConfig,
        collective: Collective,
    ) -> Result<Self> {
        let world = collective.world();
        if cfg.workers == 0 || cfg.workers % world != 0 {
            bail!(
                "--workers {} must be a positive multiple of the comm world size {world} \
                 (each rank runs workers/world producer streams)",
                cfg.workers
            );
        }
        let grad_art = rt.load(&format!("lm_grad_{}", cfg.scale))?;
        let eval_art = rt.load(&format!("lm_eval_{}", cfg.scale))?;
        let store = ParamStore::load_init(artifacts_dir, &cfg.scale, &grad_art.manifest)?;
        let adam_cfg = AdamConfig { weight_decay: cfg.weight_decay, ..AdamConfig::paper_pretrain() };
        let mut subspace =
            SubspaceSet::from_manifest(&grad_art.manifest, &store, cfg.sampler, cfg.c, adam_cfg)?;
        subspace.set_tracking(cfg.track_refresh);
        let rank_ctl = cfg.rank_adapt.map(|rc| RankController::new(rc, subspace.slots.len()));

        // full-rank trainables: outputs out[2][<name>]
        let mut full_slots = Vec::new();
        for (oi, out) in grad_art.manifest.outputs.iter().enumerate() {
            if let Some(name) = out.name.strip_prefix("out[2][").and_then(|s| s.strip_suffix(']')) {
                let param_pos = store
                    .position(&format!("[{name}]"))
                    .with_context(|| format!("full trainable {name} not in store"))?;
                let len = store.tensors()[param_pos].num_elements();
                full_slots.push(FullSlot {
                    name: name.to_string(),
                    param_pos,
                    dout: oi,
                    adam: Adam::new(len, adam_cfg),
                });
            }
        }
        if full_slots.is_empty() {
            bail!("no out[2][...] outputs in {}", grad_art.manifest.name);
        }

        // input routing
        let mut input_map = Vec::with_capacity(grad_art.manifest.inputs.len());
        let mut param_cursor = 0usize;
        for spec in &grad_art.manifest.inputs {
            if spec.name.starts_with("params") {
                input_map.push(Src::Param(param_cursor));
                param_cursor += 1;
            } else if spec.name.starts_with("bs[") {
                let slot = subspace
                    .slots
                    .iter()
                    .position(|s| s.b_input == spec.index)
                    .context("unmapped bs input")?;
                input_map.push(Src::B(slot));
            } else if spec.name.starts_with("vs[") {
                let slot = subspace
                    .slots
                    .iter()
                    .position(|s| s.v_input == spec.index)
                    .context("unmapped vs input")?;
                input_map.push(Src::V(slot));
            } else if spec.name == "tokens" {
                input_map.push(Src::Tokens);
            } else {
                bail!("unexpected input {} in {}", spec.name, grad_art.manifest.name);
            }
        }

        let db_outs: Vec<usize> = subspace.slots.iter().map(|s| s.db_output).collect();
        let f_douts: Vec<usize> = full_slots.iter().map(|f| f.dout).collect();
        let quality = QualityProbe::new(
            cfg.seed,
            cfg.probe_every,
            subspace.slots.iter().map(|s| s.name.clone()).collect(),
        );
        let engine = GradEstimator::new(
            MethodShape::LowRankIpa,
            0.0,
            Some(subspace),
            Vec::new(),
            full_slots,
            None,
        );

        let batch = grad_art.manifest.meta_usize("batch")?;
        let seq_len = grad_art.manifest.meta_usize("seq_len")?;
        let vocab = grad_art.manifest.meta_usize("vocab")?;
        let rng = Rng::new(cfg.seed);
        Ok(PretrainTrainer {
            cfg,
            grad_art,
            eval_art,
            store,
            engine,
            collective,
            ckpt_writer: AsyncCheckpointer::new(),
            input_map,
            rng,
            batch,
            seq_len,
            vocab,
            rank_ctl,
            db_outs,
            f_douts,
            grad_stage: Vec::new(),
            quality,
        })
    }

    /// Probe subspace slot `i` against the most recent reduced dB
    /// (`grad_stage[i][0]` — survives across steps) with a direction
    /// from the dedicated probe stream, folding the result into the
    /// slot's sentinel and the `mse_ratio`/`bias_sentinel` series.
    /// Read-only on training state; skips silently when no gradient is
    /// staged yet or the staged width is stale across a rank shrink.
    fn probe_slot_quality(&mut self, i: usize, step: u64) {
        let Some(db) = self.grad_stage.get(i).and_then(|g| g.first()) else { return };
        if db.is_empty() {
            return;
        }
        // disjoint-field borrows: quality (mut, probe direction) and
        // engine/grad_stage (shared) split without a self method call
        let probe = {
            let u = self.quality.draw_direction(db.len());
            self.engine.probe_quality(i, db, u)
        };
        if let Some(p) = probe {
            self.quality.observe(i, step, p);
        }
    }

    fn subspace(&self) -> &SubspaceSet {
        self.engine.subspace.as_ref().expect("pretrain engine always has a subspace")
    }

    /// Stage one shard's inputs — zero-copy (`Arc` bumps; the token
    /// vector is moved, not copied).
    fn build_inputs(&self, tokens: Vec<i32>) -> Vec<HostTensor> {
        let tokens_t = HostTensor::i32(vec![self.batch, self.seq_len + 1], tokens);
        self.input_map
            .iter()
            .map(|src| match src {
                Src::Param(i) => self.store.tensors()[*i].clone(),
                Src::B(s) => {
                    // staged view: compact [m, r] before any shrink,
                    // zero-padded [m, r_max] after (the artifact's fixed
                    // input shape; zero B columns contribute nothing)
                    let (shape, data) = self.subspace().slots[*s].staged_b();
                    HostTensor::f32_shared(shape, data)
                }
                Src::V(s) => {
                    let (shape, data) = self.subspace().slots[*s].staged_v();
                    HostTensor::f32_shared(shape, data)
                }
                Src::Tokens => tokens_t.clone(),
            })
            .collect()
    }

    /// Eval loss on held-out batches, at the lifted point (copy; the
    /// live B/V state is untouched).
    pub fn eval_loss(&mut self, eval_sets: &[Vec<i32>]) -> Result<f32> {
        // lifted copy of the parameters (copy-on-write: only the
        // reparameterized tensors are actually duplicated)
        let mut lifted: Vec<HostTensor> = self.store.tensors().to_vec();
        for slot in &self.engine.subspace.as_ref().expect("subspace").slots {
            let theta = lifted[slot.param_pos].as_f32_mut()?;
            crate::model::lift_into(
                theta,
                slot.b.as_slice(),
                slot.v.as_slice(),
                slot.m,
                slot.n,
                slot.r,
            );
        }
        let mut total = 0.0f32;
        for tokens in eval_sets {
            let mut inputs = lifted.clone();
            inputs.push(HostTensor::i32(vec![self.batch, self.seq_len + 1], tokens.clone()));
            let out = self.eval_art.execute(&inputs)?;
            total += out[0].scalar()?;
        }
        Ok(total / eval_sets.len() as f32)
    }

    /// Run the full training loop (optionally resuming from a
    /// checkpoint first — see [`CkptOptions`]).
    ///
    /// A thin driver over the session seam: [`Self::begin`], then
    /// [`Self::step_once`] until exhausted, then [`Self::finish_run`] —
    /// the same three calls the serve daemon schedules, so a scheduled
    /// run retraces this exact sequence bitwise.
    pub fn run(&mut self) -> Result<PretrainResult> {
        let mut lp = self.begin()?;
        while self.step_once(&mut lp)? {}
        self.finish_run(lp)
    }

    /// Open the training loop: apply the thread config, build the
    /// controller and LR schedule, restore a checkpoint when resuming,
    /// and spawn this rank's batch-producer slice.
    pub fn begin(&mut self) -> Result<PretrainLoop> {
        let cfg = self.cfg.clone();
        if cfg.threads > 0 {
            crate::kernel::set_global_threads(cfg.threads);
        }
        let controller = LazyUpdateController::new(cfg.k_interval);
        let schedule = CosineSchedule::new(cfg.lr, cfg.warmup, cfg.steps.max(cfg.warmup + 1));

        // resume before touching any stream state
        let mut start_step = 0u64;
        if let Some(resume) = cfg.ckpt.resume {
            let dir = cfg
                .ckpt
                .dir
                .as_ref()
                .context("resume requested but no checkpoint dir configured")?;
            let loaded = ckpt::load_checkpoint(dir, resume)?;
            self.restore_state(&loaded)?;
            start_step = loaded.step;
            if start_step >= cfg.steps {
                bail!(
                    "checkpoint step {start_step} is not before the target step count {}",
                    cfg.steps
                );
            }
        }

        // Data streams draw from a dedicated RNG (not `self.rng`) so the
        // trainer RNG round-trips through checkpoints exactly; producers
        // fast-forward `start_step` batches to rejoin their streams.
        // Per-worker channels drain in worker order, so the rejoin —
        // and the shard sequence itself — is exact at any worker count.
        // In a multi-process run this rank spawns only its contiguous
        // worker slice, with the identical global stream forks, so the
        // union of all ranks' shards is the single-process sequence.
        let world = self.collective.world();
        let rank = self.collective.rank();
        let local_workers = cfg.workers / world;
        let corpus = ZipfMarkovCorpus::new(self.vocab, cfg.seed ^ 0xC0FFEE);
        let mut data_rng = Rng::new(cfg.seed ^ 0xDA7A);
        let producer = BatchProducer::spawn_lm_slice(
            corpus.clone(),
            self.batch,
            self.seq_len,
            cfg.workers,
            rank * local_workers,
            local_workers,
            2,
            &mut data_rng,
            start_step,
        );
        let eval_sets = crate::data::LmBatcher::new(
            corpus,
            self.batch,
            self.seq_len,
            data_rng.fork(0xE),
        )
        .eval_batches(cfg.eval_batches, cfg.seed);

        Ok(PretrainLoop {
            controller,
            schedule,
            producer,
            eval_sets,
            log: MetricsLog::default(),
            step: start_step,
        })
    }

    /// Advance the loop by exactly one optimizer step (resample
    /// boundary, shard executes, all-reduce, clip, engine update,
    /// probes, logging, maybe-save + barrier). Returns `false` once
    /// every step has run. The operation sequence — collective calls
    /// included — is the pre-seam inline loop, verbatim.
    pub fn step_once(&mut self, lp: &mut PretrainLoop) -> Result<bool> {
        if lp.step >= self.cfg.steps {
            return Ok(false);
        }
        let cfg = self.cfg.clone();
        let step = lp.step;
        {
            let t0 = Instant::now();
            if lp.controller.action(step) == LazyAction::ResampleSubspace {
                let _p = crate::obs::phase("trainer", "resample", "step.resample_s");
                monitor::stamp(monitor::Phase::Resample, step);
                if step > 0 {
                    // boundary quality gauges: probe every slot against
                    // last step's reduced dB while V is still the frame
                    // that produced it (before the redraw below). The
                    // rank-adapt log then prints a fresh mse_ratio
                    // context column.
                    if self.quality.active() {
                        for i in 0..self.quality.n_slots() {
                            self.probe_slot_quality(i, step);
                        }
                    }
                    self.engine.subspace.as_mut().expect("subspace").lift(&mut self.store)?;
                    // rank decisions happen exactly here: B is spent
                    // (lifted), Adam is about to reset, V is about to be
                    // redrawn — a shrink is a pure re-layout
                    self.apply_rank_adaptation(step, &lp.controller)?;
                }
                self.engine.subspace.as_mut().expect("subspace").resample(&mut self.rng);
            }
            // keep the padded B staging (shrunk slots only; a no-op
            // before the first shrink) in sync with the B the engine
            // updated last step
            self.engine.subspace.as_mut().expect("subspace").refresh_stage();
            let lr = lp.schedule.lr(step);

            // one shard per local worker; all-reduce gradients across
            // shards and (when distributed) across ranks — one combine
            // order either way, so the reduced gradients are bitwise
            // identical to the single-process run
            let shards = lp.producer.next_step_shards();
            let n_shards = shards.len();
            let n_b = self.db_outs.len();
            let n_f = self.f_douts.len();
            // persistent staging: the first step allocates the
            // full-gradient buffers, every later step just copies into
            // them (taken out of `self` for the duration of the borrow)
            let mut groups = std::mem::take(&mut self.grad_stage);
            groups.resize(n_b + n_f, Vec::new());
            let mut loss_acc = 0.0f32;
            {
                let _p = crate::obs::phase("trainer", "execute", "step.execute_s");
                monitor::stamp(monitor::Phase::Execute, step);
                for (s_idx, shard) in shards.into_iter().enumerate() {
                    let inputs = self.build_inputs(shard.tokens);
                    let out = self.grad_art.execute(&inputs)?;
                    drop(inputs);
                    loss_acc += out[0].scalar()?;
                    for (si, &oi) in self.db_outs.iter().enumerate() {
                        // post-shrink slots: the artifact still emits dB
                        // at [m, r_max]; keep only the active columns so
                        // the all-reduce wire volume drops with r (the
                        // padded V columns are zero, so the dropped dB
                        // columns are exactly zero)
                        let (m, r, r_max) = {
                            let s = &self.subspace().slots[si];
                            (s.m, s.r, s.r_max)
                        };
                        stage_grad_cols(&mut groups[si], s_idx, out[oi].as_f32()?, m, r, r_max);
                    }
                    for (fi, &oi) in self.f_douts.iter().enumerate() {
                        stage_grad(&mut groups[n_b + fi], s_idx, out[oi].as_f32()?);
                    }
                }
            }
            let _p_reduce = crate::obs::phase("trainer", "reduce", "step.reduce_s");
            monitor::stamp(monitor::Phase::Reduce, step);
            let loss = self.collective.allreduce_mean_scalar(loss_acc, n_shards)?;
            // one slot-pipelined pass over every dB and full-rank slot:
            // while slot k's chunk reduce runs on the kernel pool, slot
            // k+1's ring exchange is already on the wire — arithmetic
            // (and therefore every checkpoint bit) identical to the old
            // sequential per-slot loop
            self.collective.allreduce_mean_slots(&mut groups)?;
            drop(_p_reduce);

            // global-norm clip across all gradients (paper: 1.0) — the
            // reduced gradient for slot k sits in groups[k][0]
            let mut views: Vec<&mut [f32]> =
                groups.iter_mut().map(|g| g[0].as_mut_slice()).collect();
            let grad_norm = clip_global_norm(&mut views, cfg.clip);
            drop(views);

            // one engine step: subspace-B and full-rank Adam updates,
            // both fanned out across the kernel pool (bitwise equal to
            // the serial loop)
            let slot_grads: Vec<&[f32]> = groups.iter().map(|g| g[0].as_slice()).collect();
            let _p_update = crate::obs::phase("trainer", "update", "step.update_s");
            monitor::stamp(monitor::Phase::Update, step);
            let stats = self.engine.step(
                &mut self.store,
                GradSignal::Grads {
                    loss,
                    slots: &slot_grads,
                    head: None,
                    grad_norm: Some(grad_norm),
                },
                lr,
            )?;
            drop(_p_update);
            drop(slot_grads);
            self.grad_stage = groups;

            // rotating `--probe-every` probe: one slot per probe step,
            // against the gradient this step just reduced (probe RNG
            // only — the trainer stream is untouched)
            if let Some(i) = self.quality.rotating_slot(step) {
                self.probe_slot_quality(i, step);
            }

            lp.log.push(StepRecord {
                step,
                loss: stats.loss,
                lr,
                grad_norm: stats.grad_norm,
                step_time_s: t0.elapsed().as_secs_f64(),
            });

            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                let ev = {
                    let _p = crate::obs::phase("trainer", "eval", "step.eval_s");
                    monitor::stamp(monitor::Phase::Eval, step);
                    self.eval_loss(&lp.eval_sets)?
                };
                lp.log.push_eval(step + 1, ev);
                if crate::obs::metrics::enabled() && self.collective.is_leader() {
                    // measured memory ledger beside the loss line: tracked
                    // allocator (0 when not installed as #[global_allocator])
                    // plus the kernel-reported high-water mark
                    println!(
                        "[obs] step {:>6}  heap live {:>8.1} MB  peak {:>8.1} MB  vm_hwm {:>6} MB",
                        step + 1,
                        crate::obs::TrackedAlloc::live_bytes() as f64 / 1e6,
                        crate::obs::TrackedAlloc::peak_bytes() as f64 / 1e6,
                        crate::obs::alloc::vm_hwm_kb().unwrap_or(0) / 1024,
                    );
                }
            }

            // Save barrier: every rank has folded every shard in. Only
            // the leader writes (enforced inside `save_state`); the
            // write itself is asynchronous, so the leader also does not
            // block — all ranks cross the barrier and keep stepping
            // while the IO thread commits the snapshot. The barrier
            // aligns step counts only: the checkpoint is durable at the
            // writer's next drain (next save or end of run), not at
            // barrier release.
            if cfg.ckpt.should_save(step) {
                monitor::stamp(monitor::Phase::Ckpt, step);
                let dir = cfg.ckpt.dir.as_ref().expect("should_save implies dir");
                if self.collective.is_leader() {
                    self.save_state(dir, step + 1, cfg.ckpt.keep_last)?;
                }
                self.collective.barrier()?;
            }
        }
        lp.step += 1;
        Ok(true)
    }

    /// Close the loop: drain pending async saves (surfacing any write
    /// error), final lift so the stored Θ is the trained model, finite
    /// check, observability epilogue, and producer shutdown.
    pub fn finish_run(&mut self, lp: PretrainLoop) -> Result<PretrainResult> {
        // surface any pending async save error before declaring success
        self.ckpt_writer.drain()?;
        // final lift so the stored Θ is the trained model
        self.engine.subspace.as_mut().expect("subspace").lift(&mut self.store)?;
        self.store.assert_finite()?;
        // observability epilogue (no-op unless --trace-out/--metrics-out):
        // gather every rank's metrics over the collective, export and
        // leader-merge the Chrome traces
        super::ddp::export_run_obs(&mut self.collective)?;
        lp.producer.shutdown();

        let final_eval_loss = lp.log.evals.last().map(|&(_, v)| v);
        Ok(PretrainResult {
            final_eval_loss,
            params_elements: self.store.num_elements(),
            b_elements: self.subspace().b_elements(),
            log: lp.log,
        })
    }

    /// Non-blocking check on the background checkpoint writer: joins a
    /// save that has already finished (surfacing its error), never
    /// blocks on one still in flight. See
    /// [`crate::ckpt::AsyncCheckpointer::poll`].
    pub fn poll_saves(&mut self) -> Result<()> {
        self.ckpt_writer.poll()
    }

    /// Feed the just-measured lift residuals to the rank controller and
    /// apply any shrink decisions. Runs at the lazy-update boundary,
    /// after `lift` and before `resample`.
    ///
    /// The residuals are all-reduced (mean) across ranks first. Every
    /// rank folds the identical reduced gradients, so the local values
    /// already agree — the reduce makes the cross-rank agreement a
    /// structural guarantee rather than an accident (the mean of equal
    /// f32 values is exact at any world size that is a power of two
    /// times one value, and in particular x, (x+x)/2 = x). Every rank
    /// therefore takes the identical decision with no decision
    /// broadcast, and prints its own `[rank-adapt r{rank}]` line for
    /// the launch smoke test to cross-check.
    fn apply_rank_adaptation(&mut self, step: u64, controller: &LazyUpdateController) -> Result<()> {
        if self.rank_ctl.is_none() {
            return Ok(());
        }
        let (residuals, ranks): (Vec<f64>, Vec<usize>) = {
            let sub = self.subspace();
            (sub.lift_residuals().to_vec(), sub.ranks())
        };
        let mut reduced = Vec::with_capacity(residuals.len());
        for &x in &residuals {
            reduced.push(self.collective.allreduce_mean_scalar(x as f32, 1)? as f64);
        }
        let decisions =
            self.rank_ctl.as_mut().expect("checked above").observe(&reduced, &ranks);
        let rank = self.collective.rank();
        let outer = controller.outer_index(step);
        for (i, d) in decisions.iter().enumerate() {
            // context column only: the quality probe's latest
            // variance-vs-bound gauge rides along in the decision log
            // (NaN before the first probe); decisions stay a function
            // of the lift residuals alone
            let mse = self.quality.last_mse(i);
            match *d {
                RankDecision::Pending => {}
                RankDecision::Keep { ratio } => {
                    println!(
                        "[rank-adapt r{rank}] outer={outer} {}: resid ratio {ratio:.4} \
                         mse {mse:.3} (keep r={})",
                        self.subspace().slots[i].name,
                        ranks[i],
                    );
                }
                RankDecision::Shrink { to, ratio } => {
                    println!(
                        "[rank-adapt r{rank}] outer={outer} {}: resid ratio {ratio:.4} \
                         mse {mse:.3} (shrink r{}→{to})",
                        self.subspace().slots[i].name,
                        ranks[i],
                    );
                    self.engine.shrink_slot_rank(i, to)?;
                    // drop this slot's gradient staging: the next step
                    // restages at the new [m, r] width
                    if let Some(g) = self.grad_stage.get_mut(i) {
                        g.clear();
                        g.shrink_to_fit();
                    }
                }
            }
            if !matches!(d, RankDecision::Pending) && crate::obs::metrics::enabled() {
                let key = self.subspace().rank_key(i).to_string();
                crate::obs::metrics::record_value(&key, self.subspace().slots[i].r as f64);
            }
        }
        Ok(())
    }

    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// Legacy params-only export (same binary layout as the init dumps).
    /// Full training-state durability lives in [`save_state`](Self::save_state).
    pub fn save_checkpoint(&self, dir: &Path) -> Result<()> {
        self.store.save(dir)
    }

    /// Commit the full training state — Θ, per-matrix (B, V, Adam),
    /// full-rank Adam moments, and the trainer RNG — as checkpoint
    /// `step` under `dir`.
    ///
    /// Leader-only (enforced) and asynchronous: the state dicts are
    /// snapshots by `Arc` bump (copy-on-write tensors), the write runs
    /// on the [`AsyncCheckpointer`]'s background thread, and any
    /// failure surfaces at the next save or when `run()` drains the
    /// writer at shutdown.
    pub fn save_state(&mut self, dir: &Path, step: u64, keep_last: usize) -> Result<()> {
        self.collective.assert_leader("checkpoint write")?;
        let mut full = StateDict::new();
        for fslot in &self.engine.ipa_full {
            full.merge_prefixed(&format!("adam[{}].", fslot.name), fslot.adam.state_dict());
        }
        let mut groups = vec![
            ("params".to_string(), self.store.state_dict()),
            ("subspace".to_string(), self.subspace().state_dict()),
            ("full".to_string(), full),
            ("rng".to_string(), self.rng.state_dict()),
        ];
        if let Some(ctl) = &self.rank_ctl {
            // mid-window residual observations: without them a resume
            // could take a different rank schedule than the
            // uninterrupted run
            groups.push(("rankctl".to_string(), ctl.state_dict()));
        }
        let meta = vec![
            ("trainer".to_string(), "pretrain".to_string()),
            ("scale".to_string(), self.cfg.scale.clone()),
            ("sampler".to_string(), self.cfg.sampler.name().to_string()),
            ("workers".to_string(), self.cfg.workers.to_string()),
            ("seed".to_string(), self.cfg.seed.to_string()),
        ];
        self.ckpt_writer.submit(dir.to_path_buf(), step, meta, groups, keep_last)
    }

    /// Join any in-flight background save, surfacing its error —
    /// exposed for callers that need durability before `run()` returns
    /// (e.g. manual save points).
    pub fn drain_saves(&mut self) -> Result<()> {
        self.ckpt_writer.drain()
    }

    /// Restore the full training state from a loaded checkpoint. The
    /// checkpoint must come from a pretrain run of the same scale and
    /// worker topology; everything is validated before anything mutates.
    pub fn restore_state(&mut self, loaded: &LoadedCheckpoint) -> Result<()> {
        loaded.expect_meta("trainer", "pretrain")?;
        loaded.expect_meta("scale", &self.cfg.scale)?;
        loaded.expect_meta("workers", &self.cfg.workers.to_string())?;
        // the corpus, data streams, and resample draws all derive from
        // the seed — resuming under a different one would silently
        // continue on a different trajectory
        loaded.expect_meta("seed", &self.cfg.seed.to_string())?;
        loaded.expect_meta("sampler", self.cfg.sampler.name())?;
        self.store.load_state(loaded.group("params")?)?;
        self.engine
            .subspace
            .as_mut()
            .expect("subspace")
            .load_state(loaded.group("subspace")?)?;
        let full = loaded.group("full")?;
        for fslot in &mut self.engine.ipa_full {
            fslot
                .adam
                .load_state(&full.extract_prefixed(&format!("adam[{}].", fslot.name)))
                .with_context(|| format!("full-rank slot {}", fslot.name))?;
        }
        self.rng.load_state(loaded.group("rng")?)?;
        if let Some(ctl) = &mut self.rank_ctl {
            ctl.load_state(loaded.group("rankctl").context(
                "checkpoint has no rank-controller state but --rank-adapt is on \
                 (was the checkpoint written without it?)",
            )?)?;
        }
        Ok(())
    }
}

/// [`stage_grad`] for a row-major `[rows, src_cols]` source of which
/// only the leading `cols` columns are live (a shrunk slot's dB, whose
/// dropped columns are exactly zero). Falls through to the plain copy
/// when the widths agree; otherwise compacts row by row into the
/// persistent buffer — allocation-free once the buffer has warmed up at
/// the new width.
fn stage_grad_cols(
    group: &mut Vec<Vec<f32>>,
    shard: usize,
    src: &[f32],
    rows: usize,
    cols: usize,
    src_cols: usize,
) {
    if cols == src_cols {
        stage_grad(group, shard, src);
        return;
    }
    if group.len() <= shard {
        group.push(Vec::with_capacity(rows * cols));
    }
    let dst = &mut group[shard];
    dst.clear();
    for row in 0..rows {
        dst.extend_from_slice(&src[row * src_cols..row * src_cols + cols]);
    }
}

/// Stage one shard's gradient into the persistent buffers: push on the
/// first step, plain copy in steady state (no per-step allocation).
fn stage_grad(group: &mut Vec<Vec<f32>>, shard: usize, src: &[f32]) {
    if group.len() <= shard {
        group.push(src.to_vec());
        return;
    }
    let dst = &mut group[shard];
    if dst.len() == src.len() {
        dst.copy_from_slice(src);
    } else {
        dst.clear();
        dst.extend_from_slice(src);
    }
}
