//! Fine-tuning trainer — the six-method matrix of Table 1 / Figure 6 /
//! Table 3 on the classifier artifacts.
//!
//! | method                | artifact               | estimator |
//! |-----------------------|------------------------|-----------|
//! | Zero-shot             | clf_eval               | none      |
//! | Vanilla LR            | clf_zo_full            | full-rank antithetic ZO (Example 2), SGD |
//! | {Gaussian,Stiefel,Coordinate} LowRank-LR | clf_zo_lowrank | rank-r antithetic ZO (Example 3(ii)), subspace Adam + lazy update |
//! | Vanilla IPA           | clf_ipa_grad           | full BP, Adam |
//! | LowRank-IPA           | clf_ipa_lowrank_grad   | eq. (8) dB, subspace Adam + lazy update |
//!
//! The LR family never executes a backward graph: the artifacts
//! evaluate both antithetic losses forward-only and Rust forms
//! ĝ = (F⁺−F⁻)/(2σ)·Z·Vᵀ (the paper's memory story, Table 2).
//!
//! The per-step pipeline itself lives in
//! [`crate::estimator::engine::GradEstimator`]: this trainer owns the
//! artifact wiring (input staging, output routing) and delegates draw +
//! update to the engine. Staging is zero-copy — parameters, (B, V), the
//! engine's Z buffers and the batch tokens are spliced into the input
//! list by `Arc` bump, never copied.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::metrics::{MetricsLog, StepRecord};
use super::subspace::{FullSlot, SubspaceSet};
use crate::ckpt::{
    self, AsyncCheckpointer, Checkpointable, CkptOptions, LoadedCheckpoint, StateDict,
};
use crate::data::ClassifyTask;
use crate::estimator::engine::{GradEstimator, GradSignal, MethodShape, ZoTarget};
use crate::model::ParamStore;
use crate::obs::monitor;
use crate::optim::{Adam, AdamConfig, LazyAction, LazyUpdateController};
use crate::projection::ProjectorKind;
use crate::rng::Rng;
use crate::runtime::{HostTensor, LoadedArtifact, Runtime};

/// The Table-1 method rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinetuneMethod {
    ZeroShot,
    VanillaLr,
    LowRankLr(ProjectorKind),
    VanillaIpa,
    LowRankIpa(ProjectorKind),
}

impl FinetuneMethod {
    pub fn name(&self) -> String {
        match self {
            FinetuneMethod::ZeroShot => "zero-shot".into(),
            FinetuneMethod::VanillaLr => "vanilla-lr".into(),
            FinetuneMethod::LowRankLr(k) => format!("{}-lowrank-lr", k.name()),
            FinetuneMethod::VanillaIpa => "vanilla-ipa".into(),
            FinetuneMethod::LowRankIpa(k) => format!("{}-lowrank-ipa", k.name()),
        }
    }

    /// Parse the CLI/wire spelling (`zero-shot`, `vanilla-lr`,
    /// `vanilla-ipa`, `<sampler>-lowrank-lr`, `<sampler>-lowrank-ipa`) —
    /// the inverse of [`FinetuneMethod::name`]. Shared by the `finetune`
    /// subcommand and the serve daemon's job-submission protocol.
    pub fn parse(s: &str) -> Result<FinetuneMethod> {
        Ok(match s {
            "zero-shot" => FinetuneMethod::ZeroShot,
            "vanilla-lr" => FinetuneMethod::VanillaLr,
            "vanilla-ipa" => FinetuneMethod::VanillaIpa,
            other => {
                if let Some(kind) =
                    other.strip_suffix("-lowrank-lr").and_then(ProjectorKind::parse)
                {
                    FinetuneMethod::LowRankLr(kind)
                } else if let Some(kind) =
                    other.strip_suffix("-lowrank-ipa").and_then(ProjectorKind::parse)
                {
                    FinetuneMethod::LowRankIpa(kind)
                } else {
                    bail!("unknown method {other:?} (try stiefel-lowrank-lr, vanilla-ipa, …)")
                }
            }
        })
    }

    /// The Table 1 row order.
    pub fn table1_rows() -> Vec<FinetuneMethod> {
        vec![
            FinetuneMethod::ZeroShot,
            FinetuneMethod::VanillaLr,
            FinetuneMethod::LowRankLr(ProjectorKind::Gaussian),
            FinetuneMethod::LowRankLr(ProjectorKind::Stiefel),
            FinetuneMethod::LowRankLr(ProjectorKind::Coordinate),
            FinetuneMethod::VanillaIpa,
        ]
    }

    /// The Algorithm-1 shape this method steps. ZeroShot never steps;
    /// it gets an inert FullIpa engine so the state surface (head Adam)
    /// matches the other methods.
    fn method_shape(&self) -> MethodShape {
        match self {
            FinetuneMethod::ZeroShot | FinetuneMethod::VanillaIpa => MethodShape::FullIpa,
            FinetuneMethod::VanillaLr => MethodShape::FullLr,
            FinetuneMethod::LowRankLr(_) => MethodShape::LowRankLr,
            FinetuneMethod::LowRankIpa(_) => MethodShape::LowRankIpa,
        }
    }
}

/// Fine-tuning configuration (paper §6.2.1: batch 64, lr 1e-6, lazy
/// interval 50, rank 4 — batch and lr rescaled for the proxy model).
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub task: String,
    pub method: FinetuneMethod,
    pub steps: u64,
    /// Lazy update interval K (paper: 50).
    pub k_interval: u64,
    /// LR for the IPA (backprop) family.
    pub ipa_lr: f32,
    /// LR for the ZO/LR family.
    pub zo_lr: f32,
    /// ZO perturbation scale σ.
    pub sigma: f32,
    /// Weak-unbiasedness scale c.
    pub c: f64,
    pub seed: u64,
    /// Eval set size (examples).
    pub eval_examples: usize,
    /// Kernel pool size for this run (`--threads`); > 0 resizes the
    /// process-global pool, 0 leaves it as it currently is (initially:
    /// `LOWRANK_THREADS` env, else available parallelism — or whatever
    /// a previous run in this process set). Results are bitwise
    /// identical at any value.
    pub threads: usize,
    /// Checkpoint/resume policy (default: disabled).
    pub ckpt: CkptOptions,
    /// Warm-started subspace tracking (Stiefel only; see
    /// [`crate::projection::tracking`]): full Haar redraw every this
    /// many resamples, tracked refresh otherwise. 0 = off (the
    /// paper-exact Table-1 schedule, and the default here).
    pub track_refresh: u64,
}

impl FinetuneConfig {
    pub fn quick(task: &str, method: FinetuneMethod) -> Self {
        FinetuneConfig {
            task: task.to_string(),
            method,
            steps: 300,
            k_interval: 50,
            ipa_lr: 5e-4,
            zo_lr: 2e-3,
            sigma: 1e-2,
            c: 1.0,
            seed: 2026,
            eval_examples: 256,
            threads: 0,
            ckpt: CkptOptions::default(),
            track_refresh: 0,
        }
    }
}

/// Result: accuracy + loss series + timing.
pub struct FinetuneResult {
    pub method: FinetuneMethod,
    pub task: String,
    pub accuracy: f64,
    pub log: MetricsLog,
}

enum Src {
    Param(usize),
    B(usize),
    V(usize),
    /// Engine Z buffer for subspace slot i (ZO low-rank).
    Z(usize),
    /// Engine Z buffer for full-rank target i (ZO full).
    ZFull(usize),
    ZHead,
    Sigma,
    Tokens,
    Labels,
}

/// Extracted step-loop state: everything `run()` used to keep on its
/// stack between iterations — the task, the loop RNG stream, the lazy
/// controller, the step cursor, and the metrics log. Holding it in a
/// struct lets a scheduler ([`crate::serve`]) interleave
/// [`FinetuneTrainer::step_once`] calls across many jobs while each
/// trainer retraces the exact operation sequence of an uninterrupted
/// [`FinetuneTrainer::run`].
pub struct FinetuneLoop {
    task: ClassifyTask,
    log: MetricsLog,
    controller: LazyUpdateController,
    rng: Rng,
    step: u64,
    /// ZeroShot short-circuits at `begin` (one evaluation, zero steps);
    /// `finish_run` returns this accuracy without the trainer epilogue,
    /// exactly like the pre-seam early return.
    zero_shot_acc: Option<f64>,
}

impl FinetuneLoop {
    /// Next step index to run (`== cfg.steps` once exhausted).
    pub fn step(&self) -> u64 {
        self.step
    }
}

pub struct FinetuneTrainer {
    cfg: FinetuneConfig,
    grad_art: Option<Arc<LoadedArtifact>>,
    eval_art: Arc<LoadedArtifact>,
    store: ParamStore,
    /// The Algorithm-1 pipeline: subspace state, full-rank channels,
    /// head, and every per-step workspace.
    engine: GradEstimator,
    /// Background checkpoint writer — saves never block the step loop.
    ckpt_writer: AsyncCheckpointer,
    input_map: Vec<Src>,
    rng: Rng,
    batch: usize,
    seq: usize,
    vocab: usize,
    eval_batch: usize,
    /// Cached head tensor shape for Z-head staging.
    head_shape: Vec<usize>,
    /// Artifact output slot of each subspace dB (LowRank-IPA).
    db_outs: Vec<usize>,
    /// Artifact output slot of each full-rank gradient (Vanilla IPA).
    ipa_douts: Vec<usize>,
    /// Artifact output slot of the head gradient (LowRank-IPA).
    head_dout: Option<usize>,
}

impl FinetuneTrainer {
    pub fn new(rt: &mut Runtime, artifacts_dir: &Path, cfg: FinetuneConfig) -> Result<Self> {
        Self::with_base(rt, artifacts_dir, cfg, None)
    }

    /// Construct over a caller-provided parameter store. The serve
    /// layer's base-model cache hands out copy-on-write clones
    /// ([`ParamStore::cow_clone`]) of one loaded base, so N concurrent
    /// jobs share the `Arc` payloads until each job's first divergent
    /// write. The store must hold the same tensors
    /// `ParamStore::load_init(artifacts_dir, "clf", manifest)` would
    /// produce — the cache keys on exactly that identity.
    pub fn with_base(
        rt: &mut Runtime,
        artifacts_dir: &Path,
        cfg: FinetuneConfig,
        base: Option<ParamStore>,
    ) -> Result<Self> {
        let eval_art = rt.load("clf_eval")?;
        let artifact_name = match cfg.method {
            FinetuneMethod::ZeroShot => None,
            FinetuneMethod::VanillaLr => Some("clf_zo_full"),
            FinetuneMethod::LowRankLr(_) => Some("clf_zo_lowrank"),
            FinetuneMethod::VanillaIpa => Some("clf_ipa_grad"),
            FinetuneMethod::LowRankIpa(_) => Some("clf_ipa_lowrank_grad"),
        };
        let grad_art = artifact_name.map(|n| rt.load(n)).transpose()?;
        let manifest_for_store = grad_art.as_ref().map(|a| &a.manifest).unwrap_or(&eval_art.manifest);
        let store = match base {
            Some(s) => s,
            None => ParamStore::load_init(artifacts_dir, "clf", manifest_for_store)?,
        };
        let adam_cfg = AdamConfig::default();

        let kind = match cfg.method {
            FinetuneMethod::LowRankLr(k) | FinetuneMethod::LowRankIpa(k) => Some(k),
            _ => None,
        };
        let mut subspace = match (cfg.method, &grad_art) {
            (FinetuneMethod::LowRankIpa(_), Some(a)) => Some(SubspaceSet::from_manifest(
                &a.manifest,
                &store,
                kind.unwrap(),
                cfg.c,
                adam_cfg,
            )?),
            (FinetuneMethod::LowRankLr(_), Some(a)) => Some(SubspaceSet::from_zo_manifest(
                &a.manifest,
                &store,
                kind.unwrap(),
                cfg.c,
                adam_cfg,
            )?),
            _ => None,
        };
        if let Some(sub) = &mut subspace {
            sub.set_tracking(cfg.track_refresh);
        }

        let head_pos = store.position("[head]").context("no head param")?;
        let head_len = store.tensors()[head_pos].num_elements();
        let head_shape = store.shape(head_pos).to_vec();

        // Vanilla-LR full-rank Z targets / Vanilla-IPA gradient slots.
        let mut zo_targets: Vec<ZoTarget> = Vec::new();
        let mut ipa_full: Vec<FullSlot> = Vec::new();
        if let Some(art) = &grad_art {
            for spec in &art.manifest.inputs {
                if let Some(name) =
                    spec.name.strip_prefix("zs_full[").and_then(|s| s.strip_suffix(']'))
                {
                    let pos = store.position(&format!("[{name}]")).context("zs_full param")?;
                    zo_targets.push(ZoTarget {
                        param_pos: pos,
                        m: spec.shape[0],
                        n: spec.shape[1],
                    });
                }
            }
            if cfg.method == FinetuneMethod::VanillaIpa {
                for (oi, out) in art.manifest.outputs.iter().enumerate() {
                    if let Some(name) =
                        out.name.strip_prefix("out[1][").and_then(|s| s.strip_suffix(']'))
                    {
                        let pos = store
                            .position(&format!("[{name}]"))
                            .with_context(|| format!("ipa grad target {name}"))?;
                        let len = store.tensors()[pos].num_elements();
                        ipa_full.push(FullSlot {
                            name: name.to_string(),
                            param_pos: pos,
                            dout: oi,
                            adam: Adam::new(len, adam_cfg),
                        });
                    }
                }
            }
        }

        // input routing for the grad artifact
        let mut input_map = Vec::new();
        if let Some(art) = &grad_art {
            let mut param_cursor = 0usize;
            for spec in &art.manifest.inputs {
                let src = if spec.name.starts_with("params") {
                    let s = Src::Param(param_cursor);
                    param_cursor += 1;
                    s
                } else if spec.name.starts_with("bs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::B(sub.slots.iter().position(|s| s.b_input == spec.index).unwrap())
                } else if spec.name.starts_with("zs_full[") {
                    let idx = zo_targets
                        .iter()
                        .position(|z| {
                            store.name(z.param_pos).ends_with(&spec.name[7..])
                        })
                        .context("zs_full mapping")?;
                    Src::ZFull(idx)
                } else if spec.name.starts_with("zs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::Z(sub.slots.iter().position(|s| s.b_input == spec.index).unwrap())
                } else if spec.name.starts_with("vs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::V(sub.slots.iter().position(|s| s.v_input == spec.index).unwrap())
                } else if spec.name == "z_head" {
                    Src::ZHead
                } else if spec.name == "sigma" {
                    Src::Sigma
                } else if spec.name == "tokens" {
                    Src::Tokens
                } else if spec.name == "labels" {
                    Src::Labels
                } else {
                    bail!("unexpected input {}", spec.name);
                };
                input_map.push(src);
            }
        }

        // output routing (resolved once; the step loop just indexes)
        let db_outs: Vec<usize> = match (cfg.method, &subspace) {
            (FinetuneMethod::LowRankIpa(_), Some(sub)) => {
                sub.slots.iter().map(|s| s.db_output).collect()
            }
            _ => Vec::new(),
        };
        let ipa_douts: Vec<usize> = ipa_full.iter().map(|f| f.dout).collect();
        let head_dout = match (cfg.method, &grad_art) {
            (FinetuneMethod::LowRankIpa(_), Some(art)) => Some(
                art.manifest
                    .outputs
                    .iter()
                    .position(|o| o.name == "out[2]")
                    .context("no head grad output")?,
            ),
            _ => None,
        };

        let engine = GradEstimator::new(
            cfg.method.method_shape(),
            cfg.sigma,
            subspace,
            zo_targets,
            ipa_full,
            Some((head_pos, head_len, adam_cfg)),
        );

        let meta_src = grad_art.as_ref().map(|a| &a.manifest).unwrap_or(&eval_art.manifest);
        let batch = meta_src.meta_usize("batch").unwrap_or(16);
        let seq = meta_src.meta_usize("seq_len")?;
        let vocab = meta_src.meta_usize("vocab")?;
        let eval_batch = eval_art.manifest.inputs.last().unwrap().shape[0];

        Ok(FinetuneTrainer {
            rng: Rng::new(cfg.seed),
            cfg,
            grad_art,
            eval_art,
            store,
            engine,
            ckpt_writer: AsyncCheckpointer::new(),
            input_map,
            batch,
            seq,
            vocab,
            eval_batch,
            head_shape,
            db_outs,
            ipa_douts,
            head_dout,
        })
    }

    /// Accuracy on the task's deterministic eval set.
    pub fn evaluate(&mut self, task: &ClassifyTask) -> Result<f64> {
        let examples = task.eval_set(self.cfg.eval_examples);
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in examples.chunks(self.eval_batch) {
            if chunk.len() < self.eval_batch {
                break; // artifact batch is static; drop the ragged tail
            }
            let mut tokens = Vec::with_capacity(self.eval_batch * self.seq);
            let mut labels = Vec::with_capacity(self.eval_batch);
            for ex in chunk {
                tokens.extend(&ex.tokens);
                labels.push(ex.label);
            }
            let mut inputs: Vec<HostTensor> = self.store.tensors().to_vec();
            inputs.push(HostTensor::i32(vec![self.eval_batch, self.seq], tokens));
            inputs.push(HostTensor::i32(vec![self.eval_batch], labels));
            let out = self.eval_art.execute(&inputs)?;
            correct += out[1].as_i32()?[0] as usize;
            total += self.eval_batch;
        }
        if total == 0 {
            bail!("eval set smaller than one artifact batch");
        }
        Ok(correct as f64 / total as f64)
    }

    /// Run fine-tuning; returns accuracy and the loss series.
    ///
    /// A thin driver over the session seam: [`Self::begin`], then
    /// [`Self::step_once`] until exhausted, then [`Self::finish_run`].
    /// The serve daemon ([`crate::serve`]) schedules the same three
    /// calls interleaved across jobs, so a single-job serve run
    /// retraces this exact sequence — bitwise, checkpoints included.
    pub fn run(&mut self) -> Result<FinetuneResult> {
        let mut lp = self.begin()?;
        while self.step_once(&mut lp)? {}
        self.finish_run(lp)
    }

    /// Open the training loop: apply the thread config, build the
    /// deterministic task, fork the loop RNG stream, and restore a
    /// checkpoint when resuming. For ZeroShot the evaluation happens
    /// here and the returned loop is already exhausted.
    pub fn begin(&mut self) -> Result<FinetuneLoop> {
        let cfg = self.cfg.clone();
        if cfg.threads > 0 {
            crate::kernel::set_global_threads(cfg.threads);
        }
        let task = ClassifyTask::by_name(&cfg.task, self.vocab, self.seq, cfg.seed ^ 0x7A5C)
            .with_context(|| format!("unknown task {}", cfg.task))?;
        let log = MetricsLog::default();
        let controller = LazyUpdateController::new(cfg.k_interval);
        let mut rng = self.rng.fork(1);

        if cfg.method == FinetuneMethod::ZeroShot {
            let acc = self.evaluate(&task)?;
            return Ok(FinetuneLoop {
                task,
                log,
                controller,
                rng,
                step: cfg.steps,
                zero_shot_acc: Some(acc),
            });
        }

        // resume: restore Θ, subspace, optimizer moments, and the loop
        // RNG so the continuation is the exact sequence the interrupted
        // run would have produced (fine-tuning is single-threaded, so
        // the whole trajectory is bitwise reproducible)
        let mut start_step = 0u64;
        if let Some(resume) = cfg.ckpt.resume {
            let dir = cfg
                .ckpt
                .dir
                .as_ref()
                .context("resume requested but no checkpoint dir configured")?;
            let loaded = ckpt::load_checkpoint(dir, resume)?;
            self.restore_state(&loaded, &mut rng)?;
            start_step = loaded.step;
            if start_step >= cfg.steps {
                bail!(
                    "checkpoint step {start_step} is not before the target step count {}",
                    cfg.steps
                );
            }
        }
        Ok(FinetuneLoop { task, log, controller, rng, step: start_step, zero_shot_acc: None })
    }

    /// Advance the loop by exactly one optimizer step (resample, batch
    /// draw, artifact execute, engine update, logging, maybe-save).
    /// Returns `false` once every step has run — the loop state is then
    /// ready for [`Self::finish_run`]. The operation and RNG-stream
    /// sequence is the pre-seam inline loop, verbatim.
    pub fn step_once(&mut self, lp: &mut FinetuneLoop) -> Result<bool> {
        if lp.step >= self.cfg.steps {
            return Ok(false);
        }
        let cfg = self.cfg.clone();
        let step = lp.step;
        {
            let t0 = Instant::now();
            // lazy update: resample V for the low-rank methods. The ZO
            // path keeps Θ always-lifted, so only (V, B, Adam) reset —
            // resample does all three; IPA lifts Θ first.
            if lp.controller.action(step) == LazyAction::ResampleSubspace {
                let _p = crate::obs::phase("trainer", "resample", "step.resample_s");
                monitor::stamp(monitor::Phase::Resample, step);
                if let Some(sub) = self.engine.subspace.as_mut() {
                    if step > 0 && matches!(cfg.method, FinetuneMethod::LowRankIpa(_)) {
                        sub.lift(&mut self.store)?;
                    }
                    sub.resample(&mut lp.rng);
                }
            }

            let (tokens, labels) = lp.task.train_batch(self.batch, &mut lp.rng);

            // per-step fresh randomness for the ZO paths, drawn into
            // the engine's reusable buffers (head Z first, then slots —
            // the canonical stream order)
            self.engine.draw_perturbations(&mut lp.rng);

            // assemble inputs — every payload is staged by Arc bump
            let art = self.grad_art.as_ref().unwrap().clone();
            let tokens_t = HostTensor::i32(vec![self.batch, self.seq], tokens);
            let labels_t = HostTensor::i32(vec![self.batch], labels);
            let inputs: Vec<HostTensor> = self
                .input_map
                .iter()
                .map(|src| match src {
                    Src::Param(i) => self.store.tensors()[*i].clone(),
                    Src::B(s) | Src::V(s) | Src::Z(s) => {
                        let sub = self.engine.subspace.as_ref().unwrap();
                        let slot = &sub.slots[*s];
                        match src {
                            Src::B(_) => {
                                // staged view == compact (B, V) here: the
                                // finetune trainer never shrinks ranks
                                let (shape, data) = slot.staged_b();
                                HostTensor::f32_shared(shape, data)
                            }
                            Src::V(_) => {
                                let (shape, data) = slot.staged_v();
                                HostTensor::f32_shared(shape, data)
                            }
                            Src::Z(_) => {
                                HostTensor::f32_shared(vec![slot.m, slot.r], self.engine.z_arc(*s))
                            }
                            _ => unreachable!(),
                        }
                    }
                    Src::ZFull(i) => {
                        let t = &self.engine.full_lr[*i];
                        HostTensor::f32_shared(vec![t.m, t.n], self.engine.z_arc(*i))
                    }
                    Src::ZHead => {
                        HostTensor::f32_shared(self.head_shape.clone(), self.engine.head_z_arc())
                    }
                    Src::Sigma => HostTensor::scalar_f32(cfg.sigma),
                    Src::Tokens => tokens_t.clone(),
                    Src::Labels => labels_t.clone(),
                })
                .collect();

            let _p_execute = crate::obs::phase("trainer", "execute", "step.execute_s");
            monitor::stamp(monitor::Phase::Execute, step);
            let out = art.execute(&inputs)?;
            drop(_p_execute);
            // drop the staged clones so the engine's buffers are unique
            // again — the updates below then mutate in place
            drop(inputs);

            // apply the method's update through the engine
            let _p_update = crate::obs::phase("trainer", "update", "step.update_s");
            monitor::stamp(monitor::Phase::Update, step);
            let stats = match cfg.method {
                FinetuneMethod::VanillaIpa => {
                    let slot_grads: Vec<&[f32]> = self
                        .ipa_douts
                        .iter()
                        .map(|&oi| out[oi].as_f32())
                        .collect::<Result<_>>()?;
                    self.engine.step(
                        &mut self.store,
                        GradSignal::Grads {
                            loss: out[0].scalar()?,
                            slots: &slot_grads,
                            head: None,
                            grad_norm: None,
                        },
                        cfg.ipa_lr,
                    )?
                }
                FinetuneMethod::LowRankIpa(_) => {
                    let slot_grads: Vec<&[f32]> = self
                        .db_outs
                        .iter()
                        .map(|&oi| out[oi].as_f32())
                        .collect::<Result<_>>()?;
                    let head_g =
                        out[self.head_dout.context("no head grad output")?].as_f32()?;
                    self.engine.step(
                        &mut self.store,
                        GradSignal::Grads {
                            loss: out[0].scalar()?,
                            slots: &slot_grads,
                            head: Some(head_g),
                            grad_norm: None,
                        },
                        cfg.ipa_lr,
                    )?
                }
                FinetuneMethod::VanillaLr | FinetuneMethod::LowRankLr(_) => self.engine.step(
                    &mut self.store,
                    GradSignal::Antithetic {
                        f_plus: out[0].scalar()?,
                        f_minus: out[1].scalar()?,
                    },
                    cfg.zo_lr,
                )?,
                FinetuneMethod::ZeroShot => unreachable!(),
            };
            drop(_p_update);

            lp.log.push(StepRecord {
                step,
                loss: stats.loss,
                lr: match cfg.method {
                    FinetuneMethod::VanillaIpa | FinetuneMethod::LowRankIpa(_) => cfg.ipa_lr,
                    _ => cfg.zo_lr,
                },
                grad_norm: stats.grad_norm,
                step_time_s: t0.elapsed().as_secs_f64(),
            });

            if crate::obs::metrics::enabled() && (step + 1) % cfg.k_interval.max(1) == 0 {
                // measured memory ledger at every lazy-update boundary
                println!(
                    "[obs] step {:>6}  heap live {:>8.1} MB  peak {:>8.1} MB  vm_hwm {:>6} MB",
                    step + 1,
                    crate::obs::TrackedAlloc::live_bytes() as f64 / 1e6,
                    crate::obs::TrackedAlloc::peak_bytes() as f64 / 1e6,
                    crate::obs::alloc::vm_hwm_kb().unwrap_or(0) / 1024,
                );
            }

            if cfg.ckpt.should_save(step) {
                monitor::stamp(monitor::Phase::Ckpt, step);
                let dir = cfg.ckpt.dir.as_ref().expect("should_save implies dir");
                self.save_state(dir, step + 1, cfg.ckpt.keep_last, &lp.rng)?;
            }
        }
        lp.step += 1;
        Ok(true)
    }

    /// Close the loop: drain pending async saves (surfacing any write
    /// error), final lift for the IPA low-rank path, finite check,
    /// evaluation, and the observability epilogue.
    pub fn finish_run(&mut self, lp: FinetuneLoop) -> Result<FinetuneResult> {
        let cfg = self.cfg.clone();
        if let Some(acc) = lp.zero_shot_acc {
            return Ok(FinetuneResult {
                method: cfg.method,
                task: cfg.task,
                accuracy: acc,
                log: lp.log,
            });
        }
        // surface any pending async save error before declaring success
        self.ckpt_writer.drain()?;
        // final lift for the IPA low-rank path
        if matches!(cfg.method, FinetuneMethod::LowRankIpa(_)) {
            if let Some(sub) = self.engine.subspace.as_mut() {
                sub.lift(&mut self.store)?;
            }
        }
        self.store.assert_finite()?;
        let acc = {
            let _p = crate::obs::phase("trainer", "eval", "step.eval_s");
            monitor::stamp(monitor::Phase::Eval, cfg.steps);
            self.evaluate(&lp.task)?
        };
        // observability epilogue (no-op unless --trace-out/--metrics-out);
        // fine-tuning is single-process, so the gather is a world-1 copy
        super::ddp::export_run_obs(&mut super::ddp::Collective::in_process())?;
        Ok(FinetuneResult { method: cfg.method, task: cfg.task, accuracy: acc, log: lp.log })
    }

    /// Non-blocking check on the background checkpoint writer: if the
    /// in-flight save has already finished, join it and surface its
    /// result; never blocks on one still running. The serve scheduler
    /// calls this every step, so a job whose checkpoint write failed
    /// reports `failed` promptly instead of at its next save.
    pub fn poll_saves(&mut self) -> Result<()> {
        self.ckpt_writer.poll()
    }

    /// Commit the full fine-tuning state (Θ, optional subspace, head and
    /// IPA Adam moments, loop RNG) as checkpoint `step` under `dir`.
    ///
    /// Asynchronous: the dicts are `Arc`-bump snapshots handed to the
    /// background [`AsyncCheckpointer`]; failures surface at the next
    /// save or when `run()` drains the writer.
    pub fn save_state(&mut self, dir: &Path, step: u64, keep_last: usize, rng: &Rng) -> Result<()> {
        let mut opt = StateDict::new();
        let head = self.engine.head.as_ref().expect("finetune engine always has a head");
        opt.merge_prefixed("adam[head].", head.adam.state_dict());
        for fslot in &self.engine.ipa_full {
            opt.merge_prefixed(&format!("adam[{}].", fslot.name), fslot.adam.state_dict());
        }
        let mut groups = vec![
            ("params".to_string(), self.store.state_dict()),
            ("opt".to_string(), opt),
            ("rng".to_string(), rng.state_dict()),
        ];
        if let Some(sub) = &self.engine.subspace {
            groups.push(("subspace".to_string(), sub.state_dict()));
        }
        let meta = vec![
            ("trainer".to_string(), "finetune".to_string()),
            ("method".to_string(), self.cfg.method.name()),
            ("task".to_string(), self.cfg.task.clone()),
            ("seed".to_string(), self.cfg.seed.to_string()),
        ];
        self.ckpt_writer.submit(dir.to_path_buf(), step, meta, groups, keep_last)
    }

    /// Join any in-flight background save, surfacing its error.
    pub fn drain_saves(&mut self) -> Result<()> {
        self.ckpt_writer.drain()
    }

    /// Restore from a loaded checkpoint; `rng` is the training-loop RNG
    /// to rewind to the saved stream position. Validates trainer kind,
    /// method, and task before mutating anything.
    pub fn restore_state(&mut self, loaded: &LoadedCheckpoint, rng: &mut Rng) -> Result<()> {
        loaded.expect_meta("trainer", "finetune")?;
        loaded.expect_meta("method", &self.cfg.method.name())?;
        loaded.expect_meta("task", &self.cfg.task)?;
        // batches and ZO noise derive from the seed; a resume under a
        // different seed would not continue the saved trajectory
        loaded.expect_meta("seed", &self.cfg.seed.to_string())?;
        self.store.load_state(loaded.group("params")?)?;
        if let Some(sub) = &mut self.engine.subspace {
            sub.load_state(loaded.group("subspace")?)?;
        }
        let opt = loaded.group("opt")?;
        let head = self.engine.head.as_mut().expect("finetune engine always has a head");
        head.adam
            .load_state(&opt.extract_prefixed("adam[head]."))
            .context("head optimizer")?;
        for fslot in &mut self.engine.ipa_full {
            fslot
                .adam
                .load_state(&opt.extract_prefixed(&format!("adam[{}].", fslot.name)))
                .with_context(|| format!("ipa slot {}", fslot.name))?;
        }
        rng.load_state(loaded.group("rng")?)?;
        Ok(())
    }
}
