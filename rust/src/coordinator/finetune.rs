//! Fine-tuning trainer — the six-method matrix of Table 1 / Figure 6 /
//! Table 3 on the classifier artifacts.
//!
//! | method                | artifact               | estimator |
//! |-----------------------|------------------------|-----------|
//! | Zero-shot             | clf_eval               | none      |
//! | Vanilla LR            | clf_zo_full            | full-rank antithetic ZO (Example 2), SGD |
//! | {Gaussian,Stiefel,Coordinate} LowRank-LR | clf_zo_lowrank | rank-r antithetic ZO (Example 3(ii)), subspace Adam + lazy update |
//! | Vanilla IPA           | clf_ipa_grad           | full BP, Adam |
//! | LowRank-IPA           | clf_ipa_lowrank_grad   | eq. (8) dB, subspace Adam + lazy update |
//!
//! The LR family never executes a backward graph: the artifacts
//! evaluate both antithetic losses forward-only and Rust forms
//! ĝ = (F⁺−F⁻)/(2σ)·Z·Vᵀ (the paper's memory story, Table 2).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::metrics::{MetricsLog, StepRecord};
use super::subspace::SubspaceSet;
use crate::ckpt::{self, Checkpointable, CkptOptions, LoadedCheckpoint, StateDict};
use crate::data::ClassifyTask;
use crate::model::ParamStore;
use crate::optim::{Adam, AdamConfig, LazyAction, LazyUpdateController};
use crate::projection::ProjectorKind;
use crate::rng::Rng;
use crate::runtime::{HostTensor, LoadedArtifact, Runtime};

/// The Table-1 method rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinetuneMethod {
    ZeroShot,
    VanillaLr,
    LowRankLr(ProjectorKind),
    VanillaIpa,
    LowRankIpa(ProjectorKind),
}

impl FinetuneMethod {
    pub fn name(&self) -> String {
        match self {
            FinetuneMethod::ZeroShot => "zero-shot".into(),
            FinetuneMethod::VanillaLr => "vanilla-lr".into(),
            FinetuneMethod::LowRankLr(k) => format!("{}-lowrank-lr", k.name()),
            FinetuneMethod::VanillaIpa => "vanilla-ipa".into(),
            FinetuneMethod::LowRankIpa(k) => format!("{}-lowrank-ipa", k.name()),
        }
    }

    /// The Table 1 row order.
    pub fn table1_rows() -> Vec<FinetuneMethod> {
        vec![
            FinetuneMethod::ZeroShot,
            FinetuneMethod::VanillaLr,
            FinetuneMethod::LowRankLr(ProjectorKind::Gaussian),
            FinetuneMethod::LowRankLr(ProjectorKind::Stiefel),
            FinetuneMethod::LowRankLr(ProjectorKind::Coordinate),
            FinetuneMethod::VanillaIpa,
        ]
    }
}

/// Fine-tuning configuration (paper §6.2.1: batch 64, lr 1e-6, lazy
/// interval 50, rank 4 — batch and lr rescaled for the proxy model).
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub task: String,
    pub method: FinetuneMethod,
    pub steps: u64,
    /// Lazy update interval K (paper: 50).
    pub k_interval: u64,
    /// LR for the IPA (backprop) family.
    pub ipa_lr: f32,
    /// LR for the ZO/LR family.
    pub zo_lr: f32,
    /// ZO perturbation scale σ.
    pub sigma: f32,
    /// Weak-unbiasedness scale c.
    pub c: f64,
    pub seed: u64,
    /// Eval set size (examples).
    pub eval_examples: usize,
    /// Kernel pool size for this run (`--threads`); > 0 resizes the
    /// process-global pool, 0 leaves it as it currently is (initially:
    /// `LOWRANK_THREADS` env, else available parallelism — or whatever
    /// a previous run in this process set). Results are bitwise
    /// identical at any value.
    pub threads: usize,
    /// Checkpoint/resume policy (default: disabled).
    pub ckpt: CkptOptions,
}

impl FinetuneConfig {
    pub fn quick(task: &str, method: FinetuneMethod) -> Self {
        FinetuneConfig {
            task: task.to_string(),
            method,
            steps: 300,
            k_interval: 50,
            ipa_lr: 5e-4,
            zo_lr: 2e-3,
            sigma: 1e-2,
            c: 1.0,
            seed: 2026,
            eval_examples: 256,
            threads: 0,
            ckpt: CkptOptions::default(),
        }
    }
}

/// Result: accuracy + loss series + timing.
pub struct FinetuneResult {
    pub method: FinetuneMethod,
    pub task: String,
    pub accuracy: f64,
    pub log: MetricsLog,
}

enum Src {
    Param(usize),
    B(usize),
    V(usize),
    /// Fresh per-step Z for slot i (ZO low-rank).
    Z(usize),
    /// Fresh per-step full-rank Z for full-slot i (ZO full).
    ZFull(usize),
    ZHead,
    Sigma,
    Tokens,
    Labels,
}

/// Full-rank ZO slot (Vanilla LR).
struct ZoFullSlot {
    param_pos: usize,
    m: usize,
    n: usize,
}

pub struct FinetuneTrainer {
    cfg: FinetuneConfig,
    grad_art: Option<Arc<LoadedArtifact>>,
    eval_art: Arc<LoadedArtifact>,
    store: ParamStore,
    subspace: Option<SubspaceSet>,
    zo_full_slots: Vec<ZoFullSlot>,
    /// IPA-family full slots: (name, param_pos, output_idx, adam).
    ipa_full: Vec<(String, usize, usize, Adam)>,
    head_pos: usize,
    head_adam: Adam,
    input_map: Vec<Src>,
    rng: Rng,
    batch: usize,
    seq: usize,
    vocab: usize,
    eval_batch: usize,
}

impl FinetuneTrainer {
    pub fn new(rt: &mut Runtime, artifacts_dir: &Path, cfg: FinetuneConfig) -> Result<Self> {
        let eval_art = rt.load("clf_eval")?;
        let artifact_name = match cfg.method {
            FinetuneMethod::ZeroShot => None,
            FinetuneMethod::VanillaLr => Some("clf_zo_full"),
            FinetuneMethod::LowRankLr(_) => Some("clf_zo_lowrank"),
            FinetuneMethod::VanillaIpa => Some("clf_ipa_grad"),
            FinetuneMethod::LowRankIpa(_) => Some("clf_ipa_lowrank_grad"),
        };
        let grad_art = artifact_name.map(|n| rt.load(n)).transpose()?;
        let manifest_for_store = grad_art.as_ref().map(|a| &a.manifest).unwrap_or(&eval_art.manifest);
        let store = ParamStore::load_init(artifacts_dir, "clf", manifest_for_store)?;
        let adam_cfg = AdamConfig::default();

        let kind = match cfg.method {
            FinetuneMethod::LowRankLr(k) | FinetuneMethod::LowRankIpa(k) => Some(k),
            _ => None,
        };
        let subspace = match (cfg.method, &grad_art) {
            (FinetuneMethod::LowRankIpa(_), Some(a)) => Some(SubspaceSet::from_manifest(
                &a.manifest,
                &store,
                kind.unwrap(),
                cfg.c,
                adam_cfg,
            )?),
            (FinetuneMethod::LowRankLr(_), Some(a)) => Some(SubspaceSet::from_zo_manifest(
                &a.manifest,
                &store,
                kind.unwrap(),
                cfg.c,
                adam_cfg,
            )?),
            _ => None,
        };

        let head_pos = store.position("[head]").context("no head param")?;
        let head_len = store.tensors()[head_pos].num_elements();

        // Vanilla-LR full-rank Z slots / Vanilla-IPA gradient slots.
        let mut zo_full_slots = Vec::new();
        let mut ipa_full = Vec::new();
        if let Some(art) = &grad_art {
            for spec in &art.manifest.inputs {
                if let Some(name) =
                    spec.name.strip_prefix("zs_full[").and_then(|s| s.strip_suffix(']'))
                {
                    let pos = store.position(&format!("[{name}]")).context("zs_full param")?;
                    zo_full_slots.push(ZoFullSlot {
                        param_pos: pos,
                        m: spec.shape[0],
                        n: spec.shape[1],
                    });
                }
            }
            if cfg.method == FinetuneMethod::VanillaIpa {
                for (oi, out) in art.manifest.outputs.iter().enumerate() {
                    if let Some(name) =
                        out.name.strip_prefix("out[1][").and_then(|s| s.strip_suffix(']'))
                    {
                        let pos = store
                            .position(&format!("[{name}]"))
                            .with_context(|| format!("ipa grad target {name}"))?;
                        let len = store.tensors()[pos].num_elements();
                        ipa_full.push((name.to_string(), pos, oi, Adam::new(len, adam_cfg)));
                    }
                }
            }
        }

        // input routing for the grad artifact
        let mut input_map = Vec::new();
        if let Some(art) = &grad_art {
            let mut param_cursor = 0usize;
            for spec in &art.manifest.inputs {
                let src = if spec.name.starts_with("params") {
                    let s = Src::Param(param_cursor);
                    param_cursor += 1;
                    s
                } else if spec.name.starts_with("bs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::B(sub.slots.iter().position(|s| s.b_input == spec.index).unwrap())
                } else if spec.name.starts_with("zs_full[") {
                    let idx = zo_full_slots
                        .iter()
                        .position(|z| {
                            store.name(z.param_pos).ends_with(&spec.name[7..])
                        })
                        .context("zs_full mapping")?;
                    Src::ZFull(idx)
                } else if spec.name.starts_with("zs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::Z(sub.slots.iter().position(|s| s.b_input == spec.index).unwrap())
                } else if spec.name.starts_with("vs[") {
                    let sub = subspace.as_ref().unwrap();
                    Src::V(sub.slots.iter().position(|s| s.v_input == spec.index).unwrap())
                } else if spec.name == "z_head" {
                    Src::ZHead
                } else if spec.name == "sigma" {
                    Src::Sigma
                } else if spec.name == "tokens" {
                    Src::Tokens
                } else if spec.name == "labels" {
                    Src::Labels
                } else {
                    bail!("unexpected input {}", spec.name);
                };
                input_map.push(src);
            }
        }

        let meta_src = grad_art.as_ref().map(|a| &a.manifest).unwrap_or(&eval_art.manifest);
        let batch = meta_src.meta_usize("batch").unwrap_or(16);
        let seq = meta_src.meta_usize("seq_len")?;
        let vocab = meta_src.meta_usize("vocab")?;
        let eval_batch = eval_art.manifest.inputs.last().unwrap().shape[0];

        Ok(FinetuneTrainer {
            rng: Rng::new(cfg.seed),
            cfg,
            grad_art,
            eval_art,
            store,
            subspace,
            zo_full_slots,
            ipa_full,
            head_pos,
            head_adam: Adam::new(head_len, adam_cfg),
            input_map,
            batch,
            seq,
            vocab,
            eval_batch,
        })
    }

    /// Accuracy on the task's deterministic eval set.
    pub fn evaluate(&mut self, task: &ClassifyTask) -> Result<f64> {
        let examples = task.eval_set(self.cfg.eval_examples);
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in examples.chunks(self.eval_batch) {
            if chunk.len() < self.eval_batch {
                break; // artifact batch is static; drop the ragged tail
            }
            let mut tokens = Vec::with_capacity(self.eval_batch * self.seq);
            let mut labels = Vec::with_capacity(self.eval_batch);
            for ex in chunk {
                tokens.extend(&ex.tokens);
                labels.push(ex.label);
            }
            let mut inputs: Vec<HostTensor> = self.store.tensors().to_vec();
            inputs.push(HostTensor::i32(vec![self.eval_batch, self.seq], tokens));
            inputs.push(HostTensor::i32(vec![self.eval_batch], labels));
            let out = self.eval_art.execute(&inputs)?;
            correct += out[1].as_i32()?[0] as usize;
            total += self.eval_batch;
        }
        if total == 0 {
            bail!("eval set smaller than one artifact batch");
        }
        Ok(correct as f64 / total as f64)
    }

    fn fresh_normals(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    /// Run fine-tuning; returns accuracy and the loss series.
    pub fn run(&mut self) -> Result<FinetuneResult> {
        let cfg = self.cfg.clone();
        if cfg.threads > 0 {
            crate::kernel::set_global_threads(cfg.threads);
        }
        let task = ClassifyTask::by_name(&cfg.task, self.vocab, self.seq, cfg.seed ^ 0x7A5C)
            .with_context(|| format!("unknown task {}", cfg.task))?;
        let mut log = MetricsLog::default();

        if cfg.method == FinetuneMethod::ZeroShot {
            let acc = self.evaluate(&task)?;
            return Ok(FinetuneResult { method: cfg.method, task: cfg.task, accuracy: acc, log });
        }

        let controller = LazyUpdateController::new(cfg.k_interval);
        let mut rng = self.rng.fork(1);

        // resume: restore Θ, subspace, optimizer moments, and the loop
        // RNG so the continuation is the exact sequence the interrupted
        // run would have produced (fine-tuning is single-threaded, so
        // the whole trajectory is bitwise reproducible)
        let mut start_step = 0u64;
        if let Some(resume) = cfg.ckpt.resume {
            let dir = cfg
                .ckpt
                .dir
                .as_ref()
                .context("resume requested but no checkpoint dir configured")?;
            let loaded = ckpt::load_checkpoint(dir, resume)?;
            self.restore_state(&loaded, &mut rng)?;
            start_step = loaded.step;
            if start_step >= cfg.steps {
                bail!(
                    "checkpoint step {start_step} is not before the target step count {}",
                    cfg.steps
                );
            }
        }

        for step in start_step..cfg.steps {
            let t0 = Instant::now();
            // lazy update: resample V for the low-rank methods
            if let Some(sub) = &mut self.subspace {
                if controller.action(step) == LazyAction::ResampleSubspace {
                    if step > 0 && matches!(cfg.method, FinetuneMethod::LowRankIpa(_)) {
                        sub.lift(&mut self.store)?;
                    }
                    // ZO keeps Θ always-lifted, so only V/B/Adam reset
                    if matches!(cfg.method, FinetuneMethod::LowRankLr(_)) {
                        for slot in &mut sub.slots {
                            slot.b.iter_mut().for_each(|x| *x = 0.0);
                        }
                    }
                    sub.resample(&mut rng);
                }
            }

            let (tokens, labels) = task.train_batch(self.batch, &mut rng);

            // per-step fresh randomness for the ZO paths
            let z_head_len = self.store.tensors()[self.head_pos].num_elements();
            let z_head: Vec<f32> = match cfg.method {
                FinetuneMethod::VanillaLr | FinetuneMethod::LowRankLr(_) => {
                    Self::fresh_normals(&mut rng, z_head_len)
                }
                _ => vec![0.0; z_head_len],
            };
            let zs: Vec<Vec<f32>> = match cfg.method {
                FinetuneMethod::LowRankLr(_) => self
                    .subspace
                    .as_ref()
                    .unwrap()
                    .slots
                    .iter()
                    .map(|s| Self::fresh_normals(&mut rng, s.m * s.r))
                    .collect(),
                FinetuneMethod::VanillaLr => self
                    .zo_full_slots
                    .iter()
                    .map(|s| Self::fresh_normals(&mut rng, s.m * s.n))
                    .collect(),
                _ => Vec::new(),
            };

            // assemble inputs
            let art = self.grad_art.as_ref().unwrap().clone();
            let inputs: Vec<HostTensor> = self
                .input_map
                .iter()
                .map(|src| match src {
                    Src::Param(i) => self.store.tensors()[*i].clone(),
                    Src::B(s) | Src::V(s) | Src::Z(s) => {
                        let sub = self.subspace.as_ref().unwrap();
                        let slot = &sub.slots[*s];
                        match src {
                            Src::B(_) => HostTensor::f32(vec![slot.m, slot.r], slot.b.clone()),
                            Src::V(_) => HostTensor::f32(vec![slot.n, slot.r], slot.v.clone()),
                            Src::Z(_) => HostTensor::f32(vec![slot.m, slot.r], zs[*s].clone()),
                            _ => unreachable!(),
                        }
                    }
                    Src::ZFull(i) => {
                        let z = &self.zo_full_slots[*i];
                        HostTensor::f32(vec![z.m, z.n], zs[*i].clone())
                    }
                    Src::ZHead => {
                        let shape = self.store.shape(self.head_pos).to_vec();
                        HostTensor::f32(shape, z_head.clone())
                    }
                    Src::Sigma => HostTensor::scalar_f32(cfg.sigma),
                    Src::Tokens => HostTensor::i32(vec![self.batch, self.seq], tokens.clone()),
                    Src::Labels => HostTensor::i32(vec![self.batch], labels.clone()),
                })
                .collect();

            let out = art.execute(&inputs)?;

            // apply the method's update
            let (loss, grad_norm) = match cfg.method {
                FinetuneMethod::VanillaIpa => {
                    let loss = out[0].scalar()?;
                    let mut norm_sq = 0f64;
                    for (_, pos, oi, adam) in &mut self.ipa_full {
                        let g = out[*oi].as_f32()?;
                        norm_sq += g.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                        adam.step(self.store.f32_mut(*pos)?, g, cfg.ipa_lr);
                    }
                    (loss, norm_sq.sqrt() as f32)
                }
                FinetuneMethod::LowRankIpa(_) => {
                    let loss = out[0].scalar()?;
                    let sub = self.subspace.as_mut().unwrap();
                    let mut norm_sq = 0f64;
                    let mut grads: Vec<&[f32]> = Vec::with_capacity(sub.slots.len());
                    for slot in &sub.slots {
                        let g = out[slot.db_output].as_f32()?;
                        norm_sq += g.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                        grads.push(g);
                    }
                    // per-slot Adam steps fan out across the kernel pool
                    sub.adam_step_all(&grads, cfg.ipa_lr);
                    // head gradient is out[2]
                    let head_out = art
                        .manifest
                        .outputs
                        .iter()
                        .position(|o| o.name == "out[2]")
                        .context("no head grad output")?;
                    let g = out[head_out].as_f32()?.to_vec();
                    self.head_adam.step(self.store.f32_mut(self.head_pos)?, &g, cfg.ipa_lr);
                    (loss, norm_sq.sqrt() as f32)
                }
                FinetuneMethod::LowRankLr(_) => {
                    let (fp, fm) = (out[0].scalar()?, out[1].scalar()?);
                    let scale = (fp - fm) / (2.0 * cfg.sigma);
                    let sub = self.subspace.as_mut().unwrap();
                    // ĝ_B = scale·Z ; Adam step on B, then push the
                    // *delta* into Θ so Θ stays the lifted point. Each
                    // slot touches its own (B, Adam, Θ) triple, so the
                    // whole update fans out across the kernel pool.
                    let positions: Vec<usize> =
                        sub.slots.iter().map(|s| s.param_pos).collect();
                    let thetas = self.store.f32_mut_many(&positions)?;
                    let zo_lr = cfg.zo_lr;
                    let pool = crate::kernel::global();
                    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    for ((slot, theta), z) in sub.slots.iter_mut().zip(thetas).zip(&zs) {
                        tasks.push(Box::new(move || {
                            let g: Vec<f32> = z.iter().map(|x| scale * x).collect();
                            let old_b = slot.b.clone();
                            slot.adam.step(&mut slot.b, &g, zo_lr);
                            let delta: Vec<f32> =
                                slot.b.iter().zip(&old_b).map(|(n, o)| n - o).collect();
                            crate::kernel::serial::gemm_nt(
                                1.0f32, &delta, &slot.v, theta, slot.m, slot.n, slot.r,
                            );
                        }));
                    }
                    pool.run(tasks);
                    let gh: Vec<f32> = z_head.iter().map(|x| scale * x).collect();
                    self.head_adam.step(self.store.f32_mut(self.head_pos)?, &gh, cfg.zo_lr);
                    ((fp + fm) * 0.5, scale.abs())
                }
                FinetuneMethod::VanillaLr => {
                    let (fp, fm) = (out[0].scalar()?, out[1].scalar()?);
                    let scale = (fp - fm) / (2.0 * cfg.sigma);
                    // MeZO-style SGD: Θ ← Θ − lr·scale·Z (kernel AXPY;
                    // −(lr·scale)·z ≡ the old `t -= lr·scale·z` to the bit)
                    let pool = crate::kernel::global();
                    let alpha = -(cfg.zo_lr * scale);
                    for (slot, z) in self.zo_full_slots.iter().zip(&zs) {
                        let theta = self.store.f32_mut(slot.param_pos)?;
                        crate::kernel::axpy(&pool, alpha, z, theta);
                    }
                    let head = self.store.f32_mut(self.head_pos)?;
                    crate::kernel::axpy(&pool, alpha, &z_head, head);
                    ((fp + fm) * 0.5, scale.abs())
                }
                FinetuneMethod::ZeroShot => unreachable!(),
            };

            log.push(StepRecord {
                step,
                loss,
                lr: match cfg.method {
                    FinetuneMethod::VanillaIpa | FinetuneMethod::LowRankIpa(_) => cfg.ipa_lr,
                    _ => cfg.zo_lr,
                },
                grad_norm,
                step_time_s: t0.elapsed().as_secs_f64(),
            });

            if cfg.ckpt.should_save(step) {
                let dir = cfg.ckpt.dir.as_ref().expect("should_save implies dir");
                self.save_state(dir, step + 1, cfg.ckpt.keep_last, &rng)?;
            }
        }

        // final lift for the IPA low-rank path
        if let (FinetuneMethod::LowRankIpa(_), Some(sub)) = (cfg.method, &mut self.subspace) {
            sub.lift(&mut self.store)?;
        }
        self.store.assert_finite()?;
        let acc = self.evaluate(&task)?;
        Ok(FinetuneResult { method: cfg.method, task: cfg.task, accuracy: acc, log })
    }

    /// Commit the full fine-tuning state (Θ, optional subspace, head and
    /// IPA Adam moments, loop RNG) as checkpoint `step` under `dir`.
    pub fn save_state(&self, dir: &Path, step: u64, keep_last: usize, rng: &Rng) -> Result<()> {
        let mut opt = StateDict::new();
        opt.merge_prefixed("adam[head].", self.head_adam.state_dict());
        for (name, _, _, adam) in &self.ipa_full {
            opt.merge_prefixed(&format!("adam[{name}]."), adam.state_dict());
        }
        let mut groups = vec![
            ("params", self.store.state_dict()),
            ("opt", opt),
            ("rng", rng.state_dict()),
        ];
        if let Some(sub) = &self.subspace {
            groups.push(("subspace", sub.state_dict()));
        }
        let meta = [
            ("trainer", "finetune".to_string()),
            ("method", self.cfg.method.name()),
            ("task", self.cfg.task.clone()),
            ("seed", self.cfg.seed.to_string()),
        ];
        ckpt::save_checkpoint(dir, step, &meta, &groups, keep_last)?;
        Ok(())
    }

    /// Restore from a loaded checkpoint; `rng` is the training-loop RNG
    /// to rewind to the saved stream position. Validates trainer kind,
    /// method, and task before mutating anything.
    pub fn restore_state(&mut self, loaded: &LoadedCheckpoint, rng: &mut Rng) -> Result<()> {
        loaded.expect_meta("trainer", "finetune")?;
        loaded.expect_meta("method", &self.cfg.method.name())?;
        loaded.expect_meta("task", &self.cfg.task)?;
        // batches and ZO noise derive from the seed; a resume under a
        // different seed would not continue the saved trajectory
        loaded.expect_meta("seed", &self.cfg.seed.to_string())?;
        self.store.load_state(loaded.group("params")?)?;
        if let Some(sub) = &mut self.subspace {
            sub.load_state(loaded.group("subspace")?)?;
        }
        let opt = loaded.group("opt")?;
        self.head_adam
            .load_state(&opt.extract_prefixed("adam[head]."))
            .context("head optimizer")?;
        for (name, _, _, adam) in &mut self.ipa_full {
            adam.load_state(&opt.extract_prefixed(&format!("adam[{name}].")))
                .with_context(|| format!("ipa slot {name}"))?;
        }
        rng.load_state(loaded.group("rng")?)?;
        Ok(())
    }
}
