//! L3 coordinator: the trainers that drive the PJRT artifacts with the
//! paper's Algorithm 1 (low-rank gradient descent with lazy update).
//!
//! The per-step pipeline itself — project→estimate→lift→update, with
//! its preallocated workspaces — is **not** implemented here: both
//! trainers construct a [`crate::estimator::engine::GradEstimator`] and
//! delegate every draw and update to it. What this layer owns is the
//! artifact wiring (zero-copy input staging, output routing), the data
//! pipeline, DDP coordination, scheduling, and checkpoint policy.
//!
//! * [`subspace`] — [`SubspaceSet`]: per-matrix (B, V, Adam) state, the
//!   resample/lift machinery the engine steps; B and V are `Arc`-backed
//!   so staging them into artifact inputs is a reference-count bump.
//! * [`pretrain`] — LowRank-IPA pretraining of the LLaMA-proxy LMs
//!   (paper §6.2.2, Figures 7–9).
//! * [`finetune`] — the six-method fine-tuning matrix of Table 1 /
//!   Figure 6 (Vanilla LR / Gaussian / Stiefel / Coordinate LowRank-LR /
//!   Vanilla IPA / LowRank-IPA) on the classifier artifacts.
//! * [`ddp`] — the data-parallel worker simulation: N producer threads
//!   feed sharded batches through a bounded channel (backpressure), the
//!   leader executes and all-reduces gradients (DESIGN.md §2). The
//!   all-reduce combines shards in a fixed pairing order on the
//!   [`crate::kernel`] pool — bitwise identical at any thread count.
//! * [`metrics`] — step records and CSV emission for the figure
//!   harnesses.
//!
//! Both trainers checkpoint through [`crate::ckpt`]: `CkptOptions` on
//! their configs controls `save_every`/`dir`/`resume`/retention, saves
//! happen at step barriers on the leader rank only, and a restore
//! round-trips Θ, (B, V), every Adam moment, and the RNG stream
//! position bit-exactly.

mod ddp;
mod finetune;
mod metrics;
mod pretrain;
mod subspace;

pub use ddp::{allreduce_mean, allreduce_mean_with, BatchProducer, LEADER_RANK};
pub use finetune::{FinetuneConfig, FinetuneMethod, FinetuneResult, FinetuneTrainer};
pub use metrics::{MetricsLog, StepRecord};
pub use pretrain::{PretrainConfig, PretrainResult, PretrainTrainer};
pub use subspace::{FullSlot, MatrixSlot, SubspaceSet};
