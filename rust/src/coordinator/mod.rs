//! L3 coordinator: the trainers that drive the PJRT artifacts with the
//! paper's Algorithm 1 (low-rank gradient descent with lazy update).
//!
//! The per-step pipeline itself — project→estimate→lift→update, with
//! its preallocated workspaces — is **not** implemented here: both
//! trainers construct a [`crate::estimator::engine::GradEstimator`] and
//! delegate every draw and update to it. What this layer owns is the
//! artifact wiring (zero-copy input staging, output routing), the data
//! pipeline, DDP coordination, scheduling, and checkpoint policy.
//!
//! * [`subspace`] — [`SubspaceSet`]: per-matrix (B, V, Adam) state, the
//!   resample/lift machinery the engine steps; B and V are `Arc`-backed
//!   so staging them into artifact inputs is a reference-count bump.
//! * [`pretrain`] — LowRank-IPA pretraining of the LLaMA-proxy LMs
//!   (paper §6.2.2, Figures 7–9).
//! * [`finetune`] — the six-method fine-tuning matrix of Table 1 /
//!   Figure 6 (Vanilla LR / Gaussian / Stiefel / Coordinate LowRank-LR /
//!   Vanilla IPA / LowRank-IPA) on the classifier artifacts.
//! * [`ddp`] — data-parallel coordination for both topologies: the
//!   in-process worker pool (per-worker bounded channels drained in
//!   worker order — deterministic shard sequences) and the
//!   [`Collective`] backend switch that folds per-rank gradient
//!   partials across a `lowrank-sge launch` world through
//!   [`crate::comm`]. One pairing-tree combine order everywhere, so
//!   in-process, 1-rank, and W-rank runs are bitwise identical; the
//!   multi-slot path (`Collective::allreduce_mean_slots`) pipelines the
//!   per-slot ring collectives — chunk reduce on the kernel pool
//!   overlapped with the next slot's exchange, window-bounded — with
//!   the identical arithmetic, and honours the comm layer's f32/bf16
//!   wire-dtype lane.
//! * [`metrics`] — step records and CSV emission for the figure
//!   harnesses.
//! * [`session`] — [`TrainSession`]: both trainers' step loops lifted
//!   into an externally-driven construct → `step()` → `finish()` seam.
//!   The standalone subcommands and the [`crate::serve`] daemon drive
//!   the *same* object, so a single-job serve run is bitwise identical
//!   to the standalone subcommand at the same seed.
//!
//! Both trainers checkpoint through [`crate::ckpt`]: `CkptOptions` on
//! their configs controls `save_every`/`dir`/`resume`/retention, saves
//! happen at step barriers on the leader rank only (enforced by the
//! `Collective` leader gate — see [`crate::coordinator::ddp`]'s module
//! docs) and run asynchronously on the
//! [`crate::ckpt::AsyncCheckpointer`]'s background thread, and a
//! restore round-trips Θ, (B, V), every Adam moment, and the RNG
//! stream position bit-exactly.

mod ddp;
mod finetune;
mod metrics;
mod pretrain;
mod session;
mod subspace;

pub use ddp::{
    allreduce_mean, allreduce_mean_with, export_run_obs, BatchProducer, Collective, Shard,
    LEADER_RANK, PIPELINE_WINDOW,
};
pub use finetune::{FinetuneConfig, FinetuneLoop, FinetuneMethod, FinetuneResult, FinetuneTrainer};
pub use metrics::{MetricsLog, StepRecord};
pub use pretrain::{PretrainConfig, PretrainLoop, PretrainResult, PretrainTrainer};
pub use session::{
    FinetuneSession, PretrainSession, SessionStatus, SessionSummary, TrainSession,
};
pub use subspace::{FullSlot, MatrixSlot, SubspaceSet};
