//! Minimal benchmarking harness (criterion is unavailable offline —
//! DESIGN.md §3). Each `rust/benches/*.rs` target is a `harness = false`
//! binary built on these helpers: warmup, N timed iterations, robust
//! stats, one `name ... median ± spread` line per case, and a CSV dump
//! compatible with the experiment results.
//!
//! Also hosts the shared allocation-counting allocator and the
//! synthetic engine fixture used by both the steady-state allocation
//! test (`tests/engine_alloc.rs`) and the `train_step` bench, so the
//! two measure exactly the same thing.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::MatrixSlot;
use crate::model::ParamStore;
use crate::optim::{Adam, AdamConfig};
use crate::runtime::{DType, HostTensor, TensorSpec};

/// The allocation-counting allocator, promoted into the observability
/// layer as [`crate::obs::TrackedAlloc`] (it now also tracks live/peak
/// bytes for the measured memory ledger). Re-exported under its
/// original name so the allocation test and benches keep reading
/// `CountingAlloc::count()` unchanged. Install per binary with
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`.
pub use crate::obs::TrackedAlloc as CountingAlloc;

/// Synthetic engine fixture: a parameter store with one m×n tensor per
/// `dims` entry `(m, n, r)` plus a trailing head vector of `head_len`
/// elements (store position `dims.len()`), and the matching low-rank
/// [`MatrixSlot`]s (artifact wiring slots unset). Deterministic
/// contents, no artifacts needed.
pub fn engine_fixture(
    dims: &[(usize, usize, usize)],
    head_len: usize,
) -> (ParamStore, Vec<MatrixSlot>) {
    let mut specs = Vec::new();
    let mut tensors = Vec::new();
    for (i, &(m, n, _)) in dims.iter().enumerate() {
        specs.push(TensorSpec {
            index: i,
            name: format!("params[w{i}]"),
            dtype: DType::F32,
            shape: vec![m, n],
        });
        tensors.push(HostTensor::f32(
            vec![m, n],
            (0..m * n).map(|k| ((k + i) as f32 * 0.01).sin() * 0.1).collect(),
        ));
    }
    specs.push(TensorSpec {
        index: dims.len(),
        name: "params[head]".into(),
        dtype: DType::F32,
        shape: vec![head_len],
    });
    tensors.push(HostTensor::f32(
        vec![head_len],
        (0..head_len).map(|k| (k as f32 * 0.02).cos() * 0.1).collect(),
    ));
    let store = ParamStore::from_parts(specs, tensors).expect("fixture specs match tensors");
    let slots = dims
        .iter()
        .enumerate()
        .map(|(i, &(m, n, r))| MatrixSlot {
            name: format!("w{i}"),
            m,
            n,
            r,
            r_max: r,
            b_input: usize::MAX,
            v_input: usize::MAX,
            db_output: usize::MAX,
            param_pos: i,
            b: Arc::new(vec![0.0; m * r]),
            v: Arc::new(vec![0.0; n * r]),
            adam: Adam::new(m * r, AdamConfig::default()),
            frame: None,
            stage_b: None,
            stage_v: None,
        })
        .collect();
    (store, slots)
}

/// Timing statistics over the measured iterations (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Throughput helper: items per second at the median.
    pub fn per_second(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / iters as f64;
    BenchStats {
        iters,
        mean_s: mean,
        median_s: times[iters / 2],
        min_s: times[0],
        max_s: times[iters - 1],
    }
}

/// Human-readable time formatting.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Print one standard report line.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "{name:<44} median {:<12} mean {:<12} min {:<12} ({} iters)",
        fmt_time(stats.median_s),
        fmt_time(stats.mean_s),
        fmt_time(stats.min_s),
        stats.iters
    );
}

/// Machine-readable bench summary: collects one entry per case and
/// writes `results/bench/BENCH_<name>.json` — the perf-trajectory
/// artifact CI and future optimisation PRs diff against. JSON is
/// hand-emitted (op names are code literals; no escaping needed
/// beyond refusing quotes loudly).
pub struct JsonReport {
    name: String,
    entries: Vec<String>,
}

impl JsonReport {
    pub fn new(name: &str) -> JsonReport {
        JsonReport { name: name.to_string(), entries: Vec::new() }
    }

    /// Record one case: `op` label, problem `size` (elements), the
    /// timing stats, and an optional wire/compute throughput in MB/s.
    pub fn entry(&mut self, op: &str, size: usize, stats: &BenchStats, mbps: Option<f64>) {
        assert!(!op.contains('"'), "bench op names must not contain quotes: {op}");
        let ns_per_op = stats.median_s * 1e9;
        let mbps = match mbps {
            Some(v) if v.is_finite() => format!("{v:.3}"),
            _ => "null".to_string(),
        };
        self.entries.push(format!(
            "{{\"op\":\"{op}\",\"size\":{size},\"ns_per_op\":{ns_per_op:.1},\"mbps\":{mbps},\
             \"median_s\":{:.9},\"mean_s\":{:.9},\"min_s\":{:.9},\"iters\":{}}}",
            stats.median_s, stats.mean_s, stats.min_s, stats.iters
        ));
    }

    /// Write `results/bench/BENCH_<name>.json` (an object with the
    /// bench name and the entry array), returning the path.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        use std::io::Write;
        let dir = std::path::Path::new("results/bench");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(
            f,
            "{{\"bench\":\"{}\",\"cases\":[\n{}\n]}}",
            self.name,
            self.entries.join(",\n")
        )?;
        Ok(path)
    }
}

/// Append `name,median_s,mean_s,min_s,max_s,iters` to a CSV under
/// results/bench/ (header written on create).
pub fn log_csv(file: &str, name: &str, stats: &BenchStats) {
    use std::io::Write;
    let dir = std::path::Path::new("results/bench");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(file);
    let fresh = !path.exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        if fresh {
            let _ = writeln!(f, "name,median_s,mean_s,min_s,max_s,iters");
        }
        let _ = writeln!(
            f,
            "{name},{},{},{},{},{}",
            stats.median_s, stats.mean_s, stats.min_s, stats.max_s, stats.iters
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench(1, 9, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert!(s.mean_s > 0.0);
        assert_eq!(s.iters, 9);
    }

    #[test]
    fn formatting_picks_sane_units() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-5).ends_with("µs"));
        assert!(fmt_time(2.5e-2).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with("s"));
    }

    #[test]
    fn per_second_inverse_of_median() {
        let s = BenchStats { iters: 1, mean_s: 0.5, median_s: 0.5, min_s: 0.5, max_s: 0.5 };
        assert!((s.per_second(10.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn json_report_emits_one_object_per_case() {
        let mut r = JsonReport::new("unit_test");
        let s = BenchStats { iters: 3, mean_s: 2e-6, median_s: 1e-6, min_s: 5e-7, max_s: 4e-6 };
        r.entry("gemm", 1024, &s, Some(123.456));
        r.entry("axpy", 64, &s, None);
        assert_eq!(r.entries.len(), 2);
        assert!(r.entries[0].contains("\"op\":\"gemm\""));
        assert!(r.entries[0].contains("\"ns_per_op\":1000.0"));
        assert!(r.entries[0].contains("\"mbps\":123.456"));
        assert!(r.entries[1].contains("\"mbps\":null"));
    }

    #[test]
    fn engine_fixture_shapes_line_up() {
        let dims = [(6usize, 4usize, 2usize), (4, 4, 1)];
        let (store, slots) = engine_fixture(&dims, 5);
        assert_eq!(store.len(), 3);
        assert_eq!(slots.len(), 2);
        for (slot, &(m, n, r)) in slots.iter().zip(&dims) {
            assert_eq!((slot.m, slot.n, slot.r), (m, n, r));
            assert_eq!(slot.b.len(), m * r);
            assert_eq!(slot.v.len(), n * r);
            assert_eq!(store.f32(slot.param_pos).unwrap().len(), m * n);
        }
        assert_eq!(store.f32(2).unwrap().len(), 5); // head
    }
}
