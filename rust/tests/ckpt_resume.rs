//! Checkpoint/resume integration on the toy estimator path — no PJRT
//! artifacts needed, so this runs everywhere the crate builds.
//!
//! The core guarantee: `train(2k)` ≡ `train(k) → save → load → train(k)`
//! **bitwise** — same per-step losses, same final parameters — because
//! the checkpoint round-trips every piece of mutable state: W, the
//! current projector V, the Adam moments, and the RNG stream position.

use std::path::{Path, PathBuf};

use lowrank_sge::ckpt::{
    load_checkpoint, save_checkpoint, Checkpointable, Layout, ResumeSpec, StateDict,
};
use lowrank_sge::estimator::engine::project_lift;
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::linalg::Mat;
use lowrank_sge::optim::{Adam, AdamConfig};
use lowrank_sge::projection::{ProjectionSampler, StiefelSampler};
use lowrank_sge::rng::Rng;

const RANK: usize = 4;
const K_INTERVAL: u64 = 5;
const LR: f32 = 5e-3;

/// A miniature Algorithm-1 loop over the §6.1 toy problem: every K
/// steps resample a Stiefel V, each step form the LowRank-IPA estimate
/// ĝ·VVᵀ at the current W and take an Adam step.
struct ToyTrainer {
    problem: ToyProblem,
    w: Vec<f32>,
    v: Mat,
    adam: Adam,
    rng: Rng,
    step: u64,
}

impl ToyTrainer {
    fn new(seed: u64) -> Self {
        let problem = ToyProblem::small(seed);
        let w0 = problem.eval_point(seed ^ 1);
        let w: Vec<f32> = w0.data.iter().map(|&x| x as f32).collect();
        let (m, n) = (problem.m, problem.n);
        ToyTrainer {
            problem,
            w,
            v: Mat::zeros(n, RANK),
            adam: Adam::new(m * n, AdamConfig::default()),
            rng: Rng::new(seed ^ 2),
            step: 0,
        }
    }

    fn w_mat(&self) -> Mat {
        Mat {
            rows: self.problem.m,
            cols: self.problem.n,
            data: self.w.iter().map(|&x| x as f64).collect(),
        }
    }

    /// One training step; returns the sample-path loss at the pre-update W.
    fn train_step(&mut self) -> f64 {
        if self.step % K_INTERVAL == 0 {
            let mut sampler = StiefelSampler::new(self.problem.n, RANK, 1.0);
            self.v = sampler.sample(&mut self.rng);
            self.adam.reset();
        }
        let a = self.problem.sample_a(&mut self.rng);
        let w_mat = self.w_mat();
        let loss = self.problem.loss(&w_mat, &a);
        let ghat = project_lift(&self.problem.ipa_estimate(&w_mat, &a), &self.v);
        let g32: Vec<f32> = ghat.data.iter().map(|&x| x as f32).collect();
        self.adam.step(&mut self.w, &g32, LR);
        self.step += 1;
        loss
    }

    fn run(&mut self, steps: u64) -> Vec<f64> {
        (0..steps).map(|_| self.train_step()).collect()
    }

    fn save(&self, dir: &Path, keep_last: usize) {
        let mut toy = StateDict::new();
        toy.put_f32("w", vec![self.problem.m, self.problem.n], self.w.clone());
        toy.put_f64_bits("v", &self.v.data);
        toy.put_u64s("step", &[self.step]);
        let groups = [
            ("toy", toy),
            ("adam", self.adam.state_dict()),
            ("rng", self.rng.state_dict()),
        ];
        let meta = [("trainer", "toy".to_string())];
        save_checkpoint(dir, self.step, &meta, &groups, keep_last).unwrap();
    }

    fn restore(&mut self, dir: &Path, spec: ResumeSpec) {
        let ckpt = load_checkpoint(dir, spec).unwrap();
        ckpt.expect_meta("trainer", "toy").unwrap();
        let toy = ckpt.group("toy").unwrap();
        self.w = toy.f32("w").unwrap().to_vec();
        self.v = Mat {
            rows: self.problem.n,
            cols: RANK,
            data: toy.f64_bits("v").unwrap(),
        };
        self.step = toy.u64("step").unwrap();
        self.adam.load_state(ckpt.group("adam").unwrap()).unwrap();
        self.rng.load_state(ckpt.group("rng").unwrap()).unwrap();
        assert_eq!(self.step, ckpt.step);
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lowrank_sge_resume_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn toy_resume_equivalence_is_bitwise() {
    // k = 12 places the save mid-outer-iteration (resamples at 10 and
    // 15), so the restored V/Adam state — not a fresh resample — must
    // carry steps 12..15.
    let k = 12u64;

    // uninterrupted reference: 2k steps
    let mut a = ToyTrainer::new(2026);
    let losses_a = a.run(2 * k);

    // interrupted: k steps, save, fresh process, load, k more steps
    let dir = fresh_dir("equiv");
    let mut b = ToyTrainer::new(2026);
    let losses_b1 = b.run(k);
    b.save(&dir, 0);
    drop(b);

    let mut c = ToyTrainer::new(9999); // wrong seed on purpose: state must come from disk
    c.restore(&dir, ResumeSpec::Latest);
    assert_eq!(c.step, k);
    let losses_c = c.run(k);

    // the first segment matches the reference prefix …
    for (x, y) in losses_a[..k as usize].iter().zip(&losses_b1) {
        assert_eq!(x.to_bits(), y.to_bits(), "prefix diverged");
    }
    // … and the resumed segment reproduces the reference *bitwise*
    for (i, (x, y)) in losses_a[k as usize..].iter().zip(&losses_c).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "loss diverged at resumed step {i}: {x} vs {y}"
        );
    }
    // final parameters identical to the last bit
    for (x, y) in a.w.iter().zip(&c.w) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    // and the RNG streams are in the same position going forward
    assert_eq!(a.rng.state(), c.rng.state());
}

#[test]
fn resume_from_specific_step_and_latest_pointer() {
    let dir = fresh_dir("specific");
    let mut t = ToyTrainer::new(7);
    for _ in 0..3 {
        t.run(4);
        t.save(&dir, 0);
    }
    assert_eq!(Layout::new(&dir).list_steps().unwrap(), vec![4, 8, 12]);
    assert_eq!(load_checkpoint(&dir, ResumeSpec::Latest).unwrap().step, 12);

    let mut back = ToyTrainer::new(7);
    back.restore(&dir, ResumeSpec::Step(8));
    assert_eq!(back.step, 8);
    // continuing from step 8 rejoins the same trajectory
    let mut reference = ToyTrainer::new(7);
    let ref_losses = reference.run(10);
    let got = back.run(2);
    assert_eq!(got[0].to_bits(), ref_losses[8].to_bits());
    assert_eq!(got[1].to_bits(), ref_losses[9].to_bits());
}

#[test]
fn retention_prunes_and_latest_tracks_newest() {
    let dir = fresh_dir("retention");
    let mut t = ToyTrainer::new(3);
    for _ in 0..5 {
        t.run(2);
        t.save(&dir, 2);
    }
    let layout = Layout::new(&dir);
    assert_eq!(layout.list_steps().unwrap(), vec![8, 10]);
    assert_eq!(layout.read_latest().unwrap(), Some(10));
    assert!(load_checkpoint(&dir, ResumeSpec::Step(2)).is_err());
}

#[test]
fn corrupted_checkpoint_is_rejected_not_loaded() {
    let dir = fresh_dir("corrupt");
    let mut t = ToyTrainer::new(11);
    t.run(6);
    t.save(&dir, 0);

    // flip one payload byte in the params shard
    let shard = Layout::new(&dir).step_dir(6).join("toy.tsr");
    let mut bytes = std::fs::read(&shard).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&shard, &bytes).unwrap();
    let err = format!("{:#}", load_checkpoint(&dir, ResumeSpec::Latest).unwrap_err());
    assert!(err.contains("CRC32"), "wanted a CRC error, got: {err}");

    // truncation is also fatal
    std::fs::write(&shard, &bytes[..bytes.len() - 7]).unwrap();
    assert!(load_checkpoint(&dir, ResumeSpec::Latest).is_err());

    // a missing shard (manifest lists it) is fatal too
    std::fs::remove_file(&shard).unwrap();
    assert!(load_checkpoint(&dir, ResumeSpec::Latest).is_err());
}

#[test]
fn mismatched_trainer_metadata_is_rejected() {
    let dir = fresh_dir("meta");
    let mut t = ToyTrainer::new(5);
    t.run(2);
    t.save(&dir, 0);
    let ckpt = load_checkpoint(&dir, ResumeSpec::Latest).unwrap();
    assert!(ckpt.expect_meta("trainer", "pretrain").is_err());
    assert!(ckpt.expect_meta("trainer", "toy").is_ok());
}
