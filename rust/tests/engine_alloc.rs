//! Steady-state allocation discipline of the estimator engine: after
//! warm-up, the LowRank-LR step loop (perturbation draw + Adam-on-B +
//! Θ delta push + head update) **and** the LowRank-IPA step loop
//! (Adam-on-B + full-rank Adam from staged gradient views) perform
//! **zero heap allocations** on a serial kernel pool — every buffer is
//! an engine workspace reused in place. This binary holds exactly one
//! test so no concurrent test can pollute the allocation counter. The
//! counting allocator and the synthetic fixture are shared with
//! `benches/train_step.rs` via `bench_util`, so the bench measures
//! exactly the same loop.

use lowrank_sge::bench_util::{engine_fixture, CountingAlloc};
use lowrank_sge::coordinator::{FullSlot, SubspaceSet};
use lowrank_sge::estimator::engine::{GradEstimator, GradSignal, MethodShape};
use lowrank_sge::model::ParamStore;
use lowrank_sge::optim::{Adam, AdamConfig};
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const DIMS: [(usize, usize, usize); 3] = [(48, 32, 4), (32, 32, 2), (40, 24, 8)];
const HEAD_LEN: usize = 24;

fn run_steps(
    engine: &mut GradEstimator,
    store: &mut ParamStore,
    rng: &mut Rng,
    from: u64,
    to: u64,
) {
    for step in from..to {
        engine.draw_perturbations(rng);
        let fp = 0.8 + (step as f32) * 0.003;
        let fm = 0.7 - (step as f32) * 0.002;
        engine
            .step(store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, 1e-3)
            .unwrap();
    }
}

fn run_ipa_steps(
    engine: &mut GradEstimator,
    store: &mut ParamStore,
    grad_views: &[&[f32]],
    steps: u64,
) {
    for _ in 0..steps {
        engine
            .step(
                store,
                GradSignal::Grads { loss: 0.5, slots: grad_views, head: None, grad_norm: None },
                1e-3,
            )
            .unwrap();
    }
}

#[test]
fn lowrank_lr_step_loop_is_allocation_free_after_warmup() {
    // serial pool: the engine runs its inline (non-boxing) path — the
    // configuration the zero-allocation contract is stated for
    lowrank_sge::kernel::set_global_threads(1);

    let (mut store, slots) = engine_fixture(&DIMS, HEAD_LEN);
    let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    let mut engine = GradEstimator::new(
        MethodShape::LowRankLr,
        1e-2,
        Some(sub),
        Vec::new(),
        Vec::new(),
        Some((DIMS.len(), HEAD_LEN, AdamConfig::default())),
    );
    let mut rng = Rng::new(7);
    engine.subspace.as_mut().unwrap().resample(&mut rng);

    // warm-up: first steps may fault in lazily-initialized state
    run_steps(&mut engine, &mut store, &mut rng, 0, 3);

    let before = CountingAlloc::count();
    run_steps(&mut engine, &mut store, &mut rng, 3, 23);
    let after = CountingAlloc::count();

    assert_eq!(
        after - before,
        0,
        "LowRank-LR steady-state step loop allocated {} times over 20 steps",
        after - before
    );

    // sanity: the loop actually trained (B moved off zero)
    let sub = engine.subspace.as_ref().unwrap();
    assert!(sub.slots.iter().any(|s| s.b.iter().any(|&x| x != 0.0)));

    // ---- LowRank-IPA phase: the same contract on the IPA shapes ----
    // (one test binary, so both phases share the allocation counter;
    // gradient views are staged once, outside the counted loop — the
    // pretrain trainer reuses its persistent staging the same way)
    let (mut store, slots) = engine_fixture(&DIMS, HEAD_LEN);
    let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    let full = vec![FullSlot {
        name: "head".into(),
        param_pos: DIMS.len(),
        dout: 0,
        adam: Adam::new(HEAD_LEN, AdamConfig::default()),
    }];
    let mut engine =
        GradEstimator::new(MethodShape::LowRankIpa, 0.0, Some(sub), Vec::new(), full, None);
    engine.subspace.as_mut().unwrap().resample(&mut rng);

    let mut grads: Vec<Vec<f32>> = DIMS
        .iter()
        .map(|&(m, _, r)| (0..m * r).map(|i| (i as f32 * 0.05).sin() * 1e-2).collect())
        .collect();
    grads.push((0..HEAD_LEN).map(|i| (i as f32 * 0.05).cos() * 1e-2).collect());
    let grad_views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

    run_ipa_steps(&mut engine, &mut store, &grad_views, 3);
    let before = CountingAlloc::count();
    run_ipa_steps(&mut engine, &mut store, &grad_views, 20);
    let after = CountingAlloc::count();
    assert_eq!(
        after - before,
        0,
        "LowRank-IPA steady-state step loop allocated {} times over 20 steps",
        after - before
    );
    let sub = engine.subspace.as_ref().unwrap();
    assert!(sub.slots.iter().any(|s| s.b.iter().any(|&x| x != 0.0)));
}
