//! The comm determinism contract, pinned down:
//!
//! * ring ≡ tree ≡ in-process `allreduce_mean_with`, **bitwise**, at
//!   world ∈ {1, 2, 3, 4}, for prime payload lengths (uneven ring
//!   chunks), multi-frame payloads, and degenerate lengths (empty ring
//!   chunks, scalars);
//! * results are independent of message-arrival timing (rank-staggered
//!   delays change nothing);
//! * faults are loud and bounded: a truncated frame is a CRC/EOF error,
//!   a dead peer is a timeout error — never a hang, never a silently
//!   wrong gradient;
//! * the leader-rank write discipline holds at world = 2: the
//!   non-leader skips the write, crosses the barrier, and observes the
//!   leader's committed LATEST/retention state.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use lowrank_sge::ckpt::{load_checkpoint, save_checkpoint, Layout, ResumeSpec, StateDict};
use lowrank_sge::comm::{
    wire, Algorithm, CommConfig, Communicator, Conn, Listener, TransportKind,
};
use lowrank_sge::coordinator::{allreduce_mean_with, Collective, LEADER_RANK};
use lowrank_sge::kernel::KernelPool;

static DIR_COUNTER: AtomicUsize = AtomicUsize::new(0);

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lowrank_comm_test_{tag}_{}_{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run `f(communicator)` on `world` ranks (threads), full mesh, and
/// return the per-rank results in rank order.
fn spawn_world<T, F>(world: usize, transport: TransportKind, tag: &str, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Communicator) -> T + Send + Sync,
{
    let dir = fresh_dir(tag);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.clone();
                let f = &f;
                scope.spawn(move || {
                    let cfg = CommConfig {
                        world,
                        rank: Some(rank),
                        transport,
                        rdzv_dir: dir,
                        timeout: Duration::from_secs(30),
                        algo: Algorithm::Auto,
                    };
                    f(Communicator::connect(&cfg).expect("communicator setup"))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
    })
}

/// Deterministic per-rank payload (varied sign/magnitude so float
/// addition is genuinely order-sensitive).
fn gen(rank: usize, len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let x = (i as u64)
                .wrapping_mul(6364136223846793005)
                .wrapping_add(rank as u64 * 1442695040888963407);
            let u = ((x >> 33) as f32) / (1u64 << 31) as f32 - 0.5;
            u * (1.0 + (i % 7) as f32)
        })
        .collect()
}

/// The in-process reference: the pairing-tree mean over one shard per
/// rank, on a serial pool.
fn in_process_reference(world: usize, len: usize) -> Vec<f32> {
    let mut grads: Vec<Vec<f32>> = (0..world).map(|r| gen(r, len)).collect();
    allreduce_mean_with(&KernelPool::new(1), &mut grads);
    grads.swap_remove(0)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} differs ({x} vs {y})");
    }
}

#[test]
fn ring_and_tree_match_in_process_bitwise() {
    // prime lengths (uneven ring chunks), a multi-frame length
    // (> 65536-element chunks at world 2), and non-power-of-two worlds
    for world in [1usize, 2, 3, 4] {
        for &len in &[13usize, 10_007, 150_001] {
            if len == 150_001 && world > 2 {
                continue; // multi-frame coverage needs only one world size
            }
            let expected = in_process_reference(world, len);
            for algo in [Algorithm::Ring, Algorithm::Tree] {
                let results = spawn_world(
                    world,
                    TransportKind::default_for_host(),
                    &format!("allred_{world}_{len}_{}", algo.name()),
                    |mut comm| {
                        let mut data = gen(comm.rank(), len);
                        comm.allreduce_sum_with(algo, &mut data).unwrap();
                        let pool = KernelPool::new(1);
                        lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / comm.world() as f32);
                        data
                    },
                );
                for (rank, got) in results.iter().enumerate() {
                    assert_bitwise(
                        got,
                        &expected,
                        &format!("{} world={world} len={len} rank={rank}", algo.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn degenerate_lengths_reduce_correctly() {
    // world > len: some ring chunks are empty; len == 1 is the scalar
    // (loss) path
    for &len in &[1usize, 3] {
        let world = 4;
        let expected = in_process_reference(world, len);
        for algo in [Algorithm::Ring, Algorithm::Tree] {
            let results = spawn_world(
                world,
                TransportKind::default_for_host(),
                &format!("degen_{len}_{}", algo.name()),
                |mut comm| {
                    let mut data = gen(comm.rank(), len);
                    comm.allreduce_sum_with(algo, &mut data).unwrap();
                    let pool = KernelPool::new(1);
                    lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / world as f32);
                    data
                },
            );
            for got in &results {
                assert_bitwise(got, &expected, &format!("degenerate len={len} {}", algo.name()));
            }
        }
    }
}

#[test]
fn results_are_independent_of_arrival_timing() {
    let world = 3;
    let len = 4099; // prime, tree territory under Auto
    let expected = in_process_reference(world, len);
    for round in 0..3 {
        let results = spawn_world(
            world,
            TransportKind::default_for_host(),
            &format!("timing_{round}"),
            |mut comm| {
                // stagger the ranks differently every round: arrival
                // order changes, bits must not
                let delay = ((comm.rank() + round) % world) as u64 * 17;
                std::thread::sleep(Duration::from_millis(delay));
                let mut tree = gen(comm.rank(), len);
                comm.allreduce_mean(&mut tree).unwrap(); // Auto → tree at this length
                std::thread::sleep(Duration::from_millis(delay / 2));
                let mut ring = gen(comm.rank(), len);
                comm.allreduce_sum_with(Algorithm::Ring, &mut ring).unwrap();
                let pool = KernelPool::new(1);
                lowrank_sge::kernel::scale(&pool, &mut ring, 1.0 / comm.world() as f32);
                (tree, ring)
            },
        );
        for (tree, ring) in &results {
            assert_bitwise(tree, &expected, &format!("timing round {round} (tree)"));
            assert_bitwise(ring, &expected, &format!("timing round {round} (ring)"));
        }
    }
}

#[test]
fn broadcast_all_gather_and_barrier_work() {
    let world = 3;
    let len = 257;
    let results = spawn_world(world, TransportKind::default_for_host(), "bcast", |mut comm| {
        // broadcast from a non-zero root
        let mut data = gen(comm.rank(), len);
        comm.broadcast(&mut data, 1).unwrap();
        // all-gather every rank's original payload
        let mine = gen(comm.rank(), 5);
        let mut gathered = vec![0.0f32; 5 * comm.world()];
        comm.all_gather(&mine, &mut gathered).unwrap();
        // barrier with a stagger: everyone must wait for the slowest
        let t0 = Instant::now();
        if comm.rank() == 2 {
            std::thread::sleep(Duration::from_millis(120));
        }
        comm.barrier().unwrap();
        let waited = t0.elapsed();
        (data, gathered, waited)
    });
    let root_payload = gen(1, len);
    let mut expected_gather = Vec::new();
    for r in 0..world {
        expected_gather.extend(gen(r, 5));
    }
    for (rank, (data, gathered, waited)) in results.iter().enumerate() {
        assert_bitwise(data, &root_payload, &format!("broadcast rank={rank}"));
        assert_bitwise(gathered, &expected_gather, &format!("all_gather rank={rank}"));
        assert!(
            *waited >= Duration::from_millis(100),
            "rank {rank} left the barrier after {waited:?}, before the slowest rank arrived"
        );
    }
}

#[test]
fn auto_rank_claims_are_distinct() {
    let world = 3;
    let dir = fresh_dir("autorank");
    let mut ranks = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let dir = dir.clone();
                scope.spawn(move || {
                    let cfg = CommConfig {
                        world,
                        rank: None, // claim the lowest free slot
                        transport: TransportKind::default_for_host(),
                        rdzv_dir: dir,
                        timeout: Duration::from_secs(30),
                        algo: Algorithm::Auto,
                    };
                    let mut comm = Communicator::connect(&cfg).expect("auto-rank setup");
                    // the group must be fully functional
                    let mut v = [comm.rank() as f32 + 1.0];
                    comm.allreduce_sum_with(Algorithm::Tree, &mut v).unwrap();
                    assert_eq!(v[0], 6.0); // 1 + 2 + 3
                    comm.rank()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });
    ranks.sort_unstable();
    assert_eq!(ranks, vec![0, 1, 2]);
}

#[test]
fn truncated_frame_is_a_crc_or_eof_error_not_a_hang() {
    let dir = fresh_dir("truncated");
    std::fs::create_dir_all(&dir).unwrap();
    let (listener, addr) = Listener::bind(TransportKind::Tcp, &dir, 0).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    let io = Duration::from_secs(2);
    let sender = std::thread::spawn(move || {
        let conn = Conn::connect(&addr, deadline, io).unwrap();
        // a valid frame body, corrupted in the middle, length prefix intact
        let mut body = wire::encode_body(wire::Kind::Data, 1, 0, &[1.0, 2.0, 3.0, 4.0]);
        let mid = body.len() / 2;
        body[mid] ^= 0xFF;
        conn.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        conn.write_all(&body).unwrap();
        // then a frame whose declared length never arrives
        conn.write_all(&64u32.to_le_bytes()).unwrap();
        conn.write_all(&[0u8; 10]).unwrap();
        // keep the socket open past both receive attempts
        std::thread::sleep(Duration::from_millis(500));
    });
    let conn = listener.accept(deadline, io).unwrap();
    let err = format!("{:#}", wire::recv_frame(&conn).unwrap_err());
    assert!(err.contains("CRC32"), "corruption not surfaced as CRC error: {err}");
    let t0 = Instant::now();
    let err = format!("{:#}", wire::recv_frame(&conn).unwrap_err());
    assert!(
        err.contains("timed out") || err.contains("truncated"),
        "truncation not surfaced: {err}"
    );
    assert!(t0.elapsed() < Duration::from_secs(10), "truncated frame hung");
    sender.join().unwrap();
}

#[test]
fn dead_peer_surfaces_as_an_error_within_the_timeout() {
    let dir = fresh_dir("deadpeer");
    let make_cfg = |rank: usize, dir: &PathBuf| CommConfig {
        world: 2,
        rank: Some(rank),
        transport: TransportKind::Tcp,
        rdzv_dir: dir.clone(),
        timeout: Duration::from_secs(2),
        algo: Algorithm::Tree,
    };
    let dir1 = dir.clone();
    let quitter = std::thread::spawn(move || {
        let comm = Communicator::connect(&make_cfg(1, &dir1)).unwrap();
        drop(comm); // rank 1 exits without ever entering the collective
    });
    let mut comm = Communicator::connect(&make_cfg(0, &dir)).unwrap();
    quitter.join().unwrap();
    let t0 = Instant::now();
    let mut data = vec![1.0f32; 1000];
    let err = comm.allreduce_sum_with(Algorithm::Tree, &mut data);
    assert!(err.is_err(), "all-reduce against a dead peer must fail");
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "dead peer took {:?} to surface (timeout not honored)",
        t0.elapsed()
    );
}

#[test]
fn leader_rank_discipline_world_two() {
    let world = 2;
    let ckpt_root = fresh_dir("leader_ckpt");
    let toy = || {
        let mut sd = StateDict::new();
        sd.put_f32("w", vec![2], vec![4.0, 2.0]);
        vec![("params", sd)]
    };
    let observed = spawn_world(world, TransportKind::default_for_host(), "leader", |comm| {
        let mut collective = Collective::Comm(comm);
        assert_eq!(collective.world(), 2);
        let mut wrote = false;
        // the save gate: write on the leader only, then barrier
        collective
            .leader_writes(|| {
                wrote = true;
                save_checkpoint(&ckpt_root, 5, &[], &toy(), 3).map(|_| ())
            })
            .unwrap();
        // past the barrier every rank observes the committed state
        let steps = Layout::new(&ckpt_root).list_steps().unwrap();
        let loaded = load_checkpoint(&ckpt_root, ResumeSpec::Latest).unwrap().step;
        // non-leaders must refuse direct write paths
        let guard = collective.assert_leader("checkpoint write");
        (collective.rank(), wrote, steps, loaded, guard.is_ok())
    });
    for (rank, wrote, steps, loaded, guard_ok) in observed {
        assert_eq!(wrote, rank == LEADER_RANK, "rank {rank} write gate");
        assert_eq!(guard_ok, rank == LEADER_RANK, "rank {rank} assert_leader");
        assert_eq!(steps, vec![5], "rank {rank} sees the leader's retention state");
        assert_eq!(loaded, 5, "rank {rank} follows the leader's LATEST");
    }
}

#[test]
fn gradient_averaging_matches_in_process_through_the_collective() {
    // the trainer-level contract: 2 ranks × 1 shard ≡ 1 process × 2
    // shards, through Collective::allreduce_mean_shards and the scalar
    // loss path
    let len = 10_007;
    let mut reference: Vec<Vec<f32>> = (0..2).map(|r| gen(r, len)).collect();
    let mut in_proc = Collective::in_process();
    let total = in_proc.allreduce_mean_shards(&mut reference).unwrap();
    assert_eq!(total, 2);
    let expected = reference.swap_remove(0);
    let expected_loss = in_proc.allreduce_mean_scalar(1.25 + 3.5, 2).unwrap();

    let results = spawn_world(2, TransportKind::default_for_host(), "trainer_gate", |comm| {
        let mut collective = Collective::Comm(comm);
        let mut grads = vec![gen(collective.rank(), len)];
        let total = collective.allreduce_mean_shards(&mut grads).unwrap();
        let local_loss = if collective.rank() == 0 { 1.25f32 } else { 3.5f32 };
        let loss = collective.allreduce_mean_scalar(local_loss, 1).unwrap();
        (total, grads.swap_remove(0), loss)
    });
    for (total, grad, loss) in results {
        assert_eq!(total, 2);
        assert_bitwise(&grad, &expected, "collective gradient mean");
        assert_eq!(loss.to_bits(), expected_loss.to_bits(), "collective loss mean");
    }
}
