//! The comm determinism contract, pinned down:
//!
//! * ring ≡ tree ≡ the reference reduction, **bitwise**, at world ∈
//!   {1, 2, 3, 4}, for prime payload lengths (uneven ring chunks),
//!   multi-frame payloads, and degenerate lengths (empty ring chunks,
//!   scalars) — in whichever wire dtype `LOWRANK_COMM_DTYPE` selects
//!   (the CI matrix runs this suite under both `f32` and `bf16`). On
//!   the f32 lane the reference *is* the in-process
//!   `allreduce_mean_with`; on the bf16 lane it is the documented
//!   quantize-at-source model: round every contribution to the bf16
//!   grid, sum exactly in f32 with the same pairing tree, round the
//!   total once;
//! * the compressed lane explicitly: bf16 ring ≡ bf16 tree bitwise at
//!   world ∈ {2, 4}, and a world whose ranks disagree on the wire
//!   dtype is rejected in the connect handshake;
//! * the slot pipeline (`Collective::allreduce_mean_slots`) is
//!   bitwise-identical to the serial per-slot loop, including
//!   mixed ring/tree slot schedules;
//! * results are independent of message-arrival timing (rank-staggered
//!   delays change nothing);
//! * faults are loud and bounded: a truncated frame is a CRC/EOF error,
//!   a dead peer is a timeout error — never a hang, never a silently
//!   wrong gradient;
//! * the leader-rank write discipline holds at world = 2: the
//!   non-leader skips the write, crosses the barrier, and observes the
//!   leader's committed LATEST/retention state.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use lowrank_sge::ckpt::{load_checkpoint, save_checkpoint, Layout, ResumeSpec, StateDict};
use lowrank_sge::comm::{
    wire, Algorithm, CommConfig, Communicator, Conn, Listener, TransportKind, WireDtype,
};
use lowrank_sge::coordinator::{allreduce_mean_with, Collective, LEADER_RANK};
use lowrank_sge::kernel::KernelPool;

static DIR_COUNTER: AtomicUsize = AtomicUsize::new(0);

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lowrank_comm_test_{tag}_{}_{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The suite-wide wire dtype: the CI matrix sets `LOWRANK_COMM_DTYPE`
/// to run every collective test compressed and uncompressed.
fn env_dtype() -> WireDtype {
    WireDtype::from_env().expect("LOWRANK_COMM_DTYPE must be f32 or bf16")
}

fn test_config(
    world: usize,
    rank: Option<usize>,
    transport: TransportKind,
    dir: PathBuf,
    dtype: WireDtype,
) -> CommConfig {
    CommConfig {
        world,
        rank,
        transport,
        rdzv_dir: dir,
        timeout: Duration::from_secs(30),
        algo: Algorithm::Auto,
        wire_dtype: dtype,
        run_token: None,
    }
}

/// Run `f(communicator)` on `world` ranks (threads), full mesh, in the
/// given wire dtype, and return the per-rank results in rank order.
fn spawn_world_dtype<T, F>(
    world: usize,
    transport: TransportKind,
    tag: &str,
    dtype: WireDtype,
    f: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(Communicator) -> T + Send + Sync,
{
    let dir = fresh_dir(tag);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.clone();
                let f = &f;
                scope.spawn(move || {
                    let cfg = test_config(world, Some(rank), transport, dir, dtype);
                    f(Communicator::connect(&cfg).expect("communicator setup"))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
    })
}

/// [`spawn_world_dtype`] in the suite-wide (env-selected) dtype.
fn spawn_world<T, F>(world: usize, transport: TransportKind, tag: &str, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Communicator) -> T + Send + Sync,
{
    spawn_world_dtype(world, transport, tag, env_dtype(), f)
}

/// Deterministic per-rank payload (varied sign/magnitude so float
/// addition is genuinely order-sensitive).
fn gen(rank: usize, len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let x = (i as u64)
                .wrapping_mul(6364136223846793005)
                .wrapping_add(rank as u64 * 1442695040888963407);
            let u = ((x >> 33) as f32) / (1u64 << 31) as f32 - 0.5;
            u * (1.0 + (i % 7) as f32)
        })
        .collect()
}

/// The semantic model of `allreduce_mean` in either lane. f32: the
/// in-process pairing-tree mean, verbatim. bf16 (and world > 1): round
/// every contribution to the bf16 grid, sum in exact f32 with the same
/// pairing tree in rank order, round the total once, scale. At
/// world == 1 every collective is the identity, so no rounding in
/// either lane.
fn reference_mean(world: usize, len: usize, dtype: WireDtype) -> Vec<f32> {
    let quantized = dtype == WireDtype::Bf16 && world > 1;
    let mut grads: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut g = gen(r, len);
            if quantized {
                wire::quantize_bf16(&mut g);
            }
            g
        })
        .collect();
    let pool = KernelPool::new(1);
    lowrank_sge::kernel::tree_sum_vecs(&pool, &mut grads);
    if quantized {
        wire::quantize_bf16(&mut grads[0]);
    }
    lowrank_sge::kernel::scale(&pool, &mut grads[0], 1.0 / world as f32);
    grads.swap_remove(0)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} differs ({x} vs {y})");
    }
}

#[test]
fn ring_and_tree_match_the_reference_bitwise() {
    // prime lengths (uneven ring chunks), a multi-frame length
    // (> 65536-element chunks at world 2), and non-power-of-two worlds
    let dtype = env_dtype();
    for world in [1usize, 2, 3, 4] {
        for &len in &[13usize, 10_007, 150_001] {
            if len == 150_001 && world > 2 {
                continue; // multi-frame coverage needs only one world size
            }
            let expected = reference_mean(world, len, dtype);
            for algo in [Algorithm::Ring, Algorithm::Tree] {
                let results = spawn_world(
                    world,
                    TransportKind::default_for_host(),
                    &format!("allred_{world}_{len}_{}", algo.name()),
                    |mut comm| {
                        let mut data = gen(comm.rank(), len);
                        comm.allreduce_sum_with(algo, &mut data).unwrap();
                        let pool = KernelPool::new(1);
                        lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / comm.world() as f32);
                        data
                    },
                );
                for (rank, got) in results.iter().enumerate() {
                    assert_bitwise(
                        got,
                        &expected,
                        &format!(
                            "{} world={world} len={len} rank={rank} dtype={}",
                            algo.name(),
                            dtype.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn f32_lane_matches_in_process_exactly() {
    // the uncompressed lane's stronger contract: the cross-process
    // reduction is the in-process `allreduce_mean_with`, bitwise —
    // pinned in f32 explicitly so it holds under the bf16 CI matrix too
    let world = 3;
    let len = 10_007;
    let mut grads: Vec<Vec<f32>> = (0..world).map(|r| gen(r, len)).collect();
    allreduce_mean_with(&KernelPool::new(1), &mut grads);
    let expected = grads.swap_remove(0);
    for algo in [Algorithm::Ring, Algorithm::Tree] {
        let results = spawn_world_dtype(
            world,
            TransportKind::default_for_host(),
            &format!("f32lane_{}", algo.name()),
            WireDtype::F32,
            |mut comm| {
                let mut data = gen(comm.rank(), len);
                comm.allreduce_sum_with(algo, &mut data).unwrap();
                let pool = KernelPool::new(1);
                lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / comm.world() as f32);
                data
            },
        );
        for got in &results {
            assert_bitwise(got, &expected, &format!("f32 lane {}", algo.name()));
        }
    }
}

#[test]
fn compressed_ring_equals_compressed_tree_bitwise() {
    // the bf16 acceptance criterion, explicit at world ∈ {2, 4}: both
    // algorithms, every rank, one bit pattern — and that pattern is the
    // documented quantize-at-source model
    for world in [2usize, 4] {
        for &len in &[13usize, 4099, 70_001] {
            let expected = reference_mean(world, len, WireDtype::Bf16);
            let mut per_algo = Vec::new();
            for algo in [Algorithm::Ring, Algorithm::Tree] {
                let mut results = spawn_world_dtype(
                    world,
                    TransportKind::default_for_host(),
                    &format!("bf16_{world}_{len}_{}", algo.name()),
                    WireDtype::Bf16,
                    |mut comm| {
                        let mut data = gen(comm.rank(), len);
                        comm.allreduce_sum_with(algo, &mut data).unwrap();
                        let pool = KernelPool::new(1);
                        lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / comm.world() as f32);
                        data
                    },
                );
                for (rank, got) in results.iter().enumerate() {
                    assert_bitwise(
                        got,
                        &expected,
                        &format!("bf16 {} world={world} len={len} rank={rank}", algo.name()),
                    );
                    // every value really lives on the bf16 grid (scaled
                    // by 1/world, a power of two at these worlds — an
                    // exact exponent shift that preserves grid-ness)
                    for (i, v) in got.iter().enumerate() {
                        assert_eq!(
                            v.to_bits() & 0xFFFF,
                            0,
                            "element {i} of the bf16 reduction carries low mantissa bits"
                        );
                    }
                }
                per_algo.push(results.swap_remove(0));
            }
            assert_bitwise(
                &per_algo[0],
                &per_algo[1],
                &format!("bf16 ring vs tree world={world} len={len}"),
            );
        }
    }
}

#[test]
fn loss_scalar_rides_the_f32_lane_even_under_bf16() {
    // the step-loss mean is control-path traffic: values off the bf16
    // grid must survive a compressed world bit-exactly
    let a = 1.234_567_8f32;
    let b = 2.718_281_8f32;
    let results = spawn_world_dtype(
        2,
        TransportKind::default_for_host(),
        "scalar_f32lane",
        WireDtype::Bf16,
        |comm| {
            let mut collective = Collective::Comm(comm);
            let local = if collective.rank() == 0 { a } else { b };
            collective.allreduce_mean_scalar(local, 1).unwrap()
        },
    );
    let expected = (a + b) / 2.0;
    for r in results {
        assert_eq!(r.to_bits(), expected.to_bits(), "loss scalar was compressed");
    }
}

#[test]
fn mixed_dtype_worlds_are_rejected_in_the_handshake() {
    let dir = fresh_dir("mixed_dtype");
    let dir1 = dir.clone();
    let errs: Vec<String> = std::thread::scope(|scope| {
        let r0 = scope.spawn(|| {
            let cfg = test_config(
                2,
                Some(0),
                TransportKind::default_for_host(),
                dir,
                WireDtype::F32,
            );
            format!("{:#}", Communicator::connect(&cfg).map(|_| ()).unwrap_err())
        });
        let r1 = scope.spawn(|| {
            let mut cfg = test_config(
                2,
                Some(1),
                TransportKind::default_for_host(),
                dir1,
                WireDtype::Bf16,
            );
            cfg.timeout = Duration::from_secs(5);
            format!("{:#}", Communicator::connect(&cfg).map(|_| ()).unwrap_err())
        });
        vec![r0.join().unwrap(), r1.join().unwrap()]
    });
    // the accepting side (rank 0) names the mismatch; the dialing side
    // fails loudly too (mismatch ack, or its peer hanging up on it)
    assert!(
        errs[0].contains("dtype mismatch") || errs[1].contains("dtype mismatch"),
        "no rank reported the dtype mismatch: {errs:?}"
    );
}

#[test]
fn pipelined_slots_match_the_serial_loop_bitwise() {
    // mixed slot lengths: under Auto the 13/4099 slots route to the
    // tree (draining the pipeline window) and the rest to the ring,
    // including a multi-frame slot — the schedule every rank runs is
    // still a pure function of the lengths
    let world = 2;
    let shards_per_rank = 2;
    let lens: &[usize] = &[10_007, 13, 70_001, 8192, 4099, 9001];
    let make_slots = |rank: usize| -> Vec<Vec<Vec<f32>>> {
        lens.iter()
            .enumerate()
            .map(|(k, &len)| {
                (0..shards_per_rank)
                    .map(|s| gen(rank * shards_per_rank + s + 31 * k, len))
                    .collect()
            })
            .collect()
    };
    let serial = spawn_world(world, TransportKind::default_for_host(), "slots_serial", |comm| {
        let mut collective = Collective::Comm(comm);
        let mut slots = make_slots(collective.rank());
        let mut out = Vec::new();
        for g in slots.iter_mut() {
            let total = collective.allreduce_mean_shards(g).unwrap();
            assert_eq!(total, shards_per_rank * world);
            out.push(g.swap_remove(0));
        }
        out
    });
    let pipelined =
        spawn_world(world, TransportKind::default_for_host(), "slots_pipe", |comm| {
            let mut collective = Collective::Comm(comm);
            let mut slots = make_slots(collective.rank());
            let total = collective.allreduce_mean_slots(&mut slots).unwrap();
            assert_eq!(total, shards_per_rank * world);
            slots.into_iter().map(|mut g| g.swap_remove(0)).collect::<Vec<_>>()
        });
    for rank in 0..world {
        for (k, (s, p)) in serial[rank].iter().zip(&pipelined[rank]).enumerate() {
            assert_bitwise(s, p, &format!("slot {k} rank {rank} (pipelined vs serial)"));
        }
    }
}

#[test]
fn degenerate_lengths_reduce_correctly() {
    // world > len: some ring chunks are empty; len == 1 is the scalar
    // (loss) path
    let dtype = env_dtype();
    for &len in &[1usize, 3] {
        let world = 4;
        let expected = reference_mean(world, len, dtype);
        for algo in [Algorithm::Ring, Algorithm::Tree] {
            let results = spawn_world(
                world,
                TransportKind::default_for_host(),
                &format!("degen_{len}_{}", algo.name()),
                |mut comm| {
                    let mut data = gen(comm.rank(), len);
                    comm.allreduce_sum_with(algo, &mut data).unwrap();
                    let pool = KernelPool::new(1);
                    lowrank_sge::kernel::scale(&pool, &mut data, 1.0 / world as f32);
                    data
                },
            );
            for got in &results {
                assert_bitwise(got, &expected, &format!("degenerate len={len} {}", algo.name()));
            }
        }
    }
}

#[test]
fn results_are_independent_of_arrival_timing() {
    let world = 3;
    let len = 4099; // prime, tree territory under Auto
    let expected = reference_mean(world, len, env_dtype());
    for round in 0..3 {
        let results = spawn_world(
            world,
            TransportKind::default_for_host(),
            &format!("timing_{round}"),
            |mut comm| {
                // stagger the ranks differently every round: arrival
                // order changes, bits must not
                let delay = ((comm.rank() + round) % world) as u64 * 17;
                std::thread::sleep(Duration::from_millis(delay));
                let mut tree = gen(comm.rank(), len);
                comm.allreduce_mean(&mut tree).unwrap(); // Auto → tree at this length
                std::thread::sleep(Duration::from_millis(delay / 2));
                let mut ring = gen(comm.rank(), len);
                comm.allreduce_sum_with(Algorithm::Ring, &mut ring).unwrap();
                let pool = KernelPool::new(1);
                lowrank_sge::kernel::scale(&pool, &mut ring, 1.0 / comm.world() as f32);
                (tree, ring)
            },
        );
        for (tree, ring) in &results {
            assert_bitwise(tree, &expected, &format!("timing round {round} (tree)"));
            assert_bitwise(ring, &expected, &format!("timing round {round} (ring)"));
        }
    }
}

#[test]
fn broadcast_all_gather_and_barrier_work() {
    let world = 3;
    let len = 257;
    let results = spawn_world(world, TransportKind::default_for_host(), "bcast", |mut comm| {
        // broadcast from a non-zero root (always the f32 lane)
        let mut data = gen(comm.rank(), len);
        comm.broadcast(&mut data, 1).unwrap();
        // all-gather every rank's original payload
        let mine = gen(comm.rank(), 5);
        let mut gathered = vec![0.0f32; 5 * comm.world()];
        comm.all_gather(&mine, &mut gathered).unwrap();
        // barrier with a stagger: everyone must wait for the slowest
        let t0 = Instant::now();
        if comm.rank() == 2 {
            std::thread::sleep(Duration::from_millis(120));
        }
        comm.barrier().unwrap();
        let waited = t0.elapsed();
        (data, gathered, waited)
    });
    let root_payload = gen(1, len);
    let mut expected_gather = Vec::new();
    for r in 0..world {
        expected_gather.extend(gen(r, 5));
    }
    for (rank, (data, gathered, waited)) in results.iter().enumerate() {
        assert_bitwise(data, &root_payload, &format!("broadcast rank={rank}"));
        assert_bitwise(gathered, &expected_gather, &format!("all_gather rank={rank}"));
        assert!(
            *waited >= Duration::from_millis(100),
            "rank {rank} left the barrier after {waited:?}, before the slowest rank arrived"
        );
    }
}

#[test]
fn auto_rank_claims_are_distinct() {
    let world = 3;
    let dir = fresh_dir("autorank");
    let mut ranks = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let dir = dir.clone();
                scope.spawn(move || {
                    // claim the lowest free slot
                    let cfg = test_config(
                        world,
                        None,
                        TransportKind::default_for_host(),
                        dir,
                        env_dtype(),
                    );
                    let mut comm = Communicator::connect(&cfg).expect("auto-rank setup");
                    // the group must be fully functional (1 + 2 + 3 is
                    // exact on the bf16 grid, so this holds in both lanes)
                    let mut v = [comm.rank() as f32 + 1.0];
                    comm.allreduce_sum_with(Algorithm::Tree, &mut v).unwrap();
                    assert_eq!(v[0], 6.0);
                    comm.rank()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });
    ranks.sort_unstable();
    assert_eq!(ranks, vec![0, 1, 2]);
}

#[test]
fn truncated_frame_is_a_crc_or_eof_error_not_a_hang() {
    let dir = fresh_dir("truncated");
    std::fs::create_dir_all(&dir).unwrap();
    let (listener, addr) = Listener::bind(TransportKind::Tcp, &dir, 0).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    let io = Duration::from_secs(2);
    let sender = std::thread::spawn(move || {
        let conn = Conn::connect(&addr, deadline, io).unwrap();
        // a valid frame body, corrupted in the middle, length prefix intact
        let mut body =
            wire::encode_body(wire::Kind::Data, 1, 0, &[1.0, 2.0, 3.0, 4.0], WireDtype::F32)
                .unwrap();
        let mid = body.len() / 2;
        body[mid] ^= 0xFF;
        conn.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        conn.write_all(&body).unwrap();
        // then a frame whose declared length never arrives
        conn.write_all(&64u32.to_le_bytes()).unwrap();
        conn.write_all(&[0u8; 10]).unwrap();
        // keep the socket open past both receive attempts
        std::thread::sleep(Duration::from_millis(500));
    });
    let conn = listener.accept(deadline, io).unwrap();
    let err = format!("{:#}", wire::recv_frame(&conn).unwrap_err());
    assert!(err.contains("CRC32"), "corruption not surfaced as CRC error: {err}");
    let t0 = Instant::now();
    let err = format!("{:#}", wire::recv_frame(&conn).unwrap_err());
    assert!(
        err.contains("timed out") || err.contains("truncated"),
        "truncation not surfaced: {err}"
    );
    assert!(t0.elapsed() < Duration::from_secs(10), "truncated frame hung");
    sender.join().unwrap();
}

#[test]
fn dead_peer_surfaces_as_an_error_within_the_timeout() {
    let dir = fresh_dir("deadpeer");
    let make_cfg = |rank: usize, dir: &PathBuf| {
        let mut cfg = test_config(2, Some(rank), TransportKind::Tcp, dir.clone(), env_dtype());
        cfg.timeout = Duration::from_secs(2);
        cfg.algo = Algorithm::Tree;
        cfg
    };
    let dir1 = dir.clone();
    let quitter = std::thread::spawn(move || {
        let comm = Communicator::connect(&make_cfg(1, &dir1)).unwrap();
        drop(comm); // rank 1 exits without ever entering the collective
    });
    let mut comm = Communicator::connect(&make_cfg(0, &dir)).unwrap();
    quitter.join().unwrap();
    let t0 = Instant::now();
    let mut data = vec![1.0f32; 1000];
    let err = comm.allreduce_sum_with(Algorithm::Tree, &mut data);
    assert!(err.is_err(), "all-reduce against a dead peer must fail");
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "dead peer took {:?} to surface (timeout not honored)",
        t0.elapsed()
    );
}

#[test]
fn leader_rank_discipline_world_two() {
    let world = 2;
    let ckpt_root = fresh_dir("leader_ckpt");
    let toy = || {
        let mut sd = StateDict::new();
        sd.put_f32("w", vec![2], vec![4.0, 2.0]);
        vec![("params", sd)]
    };
    let observed = spawn_world(world, TransportKind::default_for_host(), "leader", |comm| {
        let mut collective = Collective::Comm(comm);
        assert_eq!(collective.world(), 2);
        let mut wrote = false;
        // the save gate: write on the leader only, then barrier
        collective
            .leader_writes(|| {
                wrote = true;
                save_checkpoint(&ckpt_root, 5, &[], &toy(), 3).map(|_| ())
            })
            .unwrap();
        // past the barrier every rank observes the committed state
        let steps = Layout::new(&ckpt_root).list_steps().unwrap();
        let loaded = load_checkpoint(&ckpt_root, ResumeSpec::Latest).unwrap().step;
        // non-leaders must refuse direct write paths
        let guard = collective.assert_leader("checkpoint write");
        (collective.rank(), wrote, steps, loaded, guard.is_ok())
    });
    for (rank, wrote, steps, loaded, guard_ok) in observed {
        assert_eq!(wrote, rank == LEADER_RANK, "rank {rank} write gate");
        assert_eq!(guard_ok, rank == LEADER_RANK, "rank {rank} assert_leader");
        assert_eq!(steps, vec![5], "rank {rank} sees the leader's retention state");
        assert_eq!(loaded, 5, "rank {rank} follows the leader's LATEST");
    }
}

#[test]
fn gradient_averaging_matches_in_process_through_the_collective() {
    // the trainer-level f32 contract: 2 ranks × 1 shard ≡ 1 process ×
    // 2 shards, through Collective::allreduce_mean_shards and the
    // scalar loss path (pinned to the f32 lane — in-process parity is
    // exactly what compression trades away)
    let len = 10_007;
    let mut reference: Vec<Vec<f32>> = (0..2).map(|r| gen(r, len)).collect();
    let mut in_proc = Collective::in_process();
    let total = in_proc.allreduce_mean_shards(&mut reference).unwrap();
    assert_eq!(total, 2);
    let expected = reference.swap_remove(0);
    let expected_loss = in_proc.allreduce_mean_scalar(1.25 + 3.5, 2).unwrap();

    let results = spawn_world_dtype(
        2,
        TransportKind::default_for_host(),
        "trainer_gate",
        WireDtype::F32,
        |comm| {
            let mut collective = Collective::Comm(comm);
            let mut grads = vec![gen(collective.rank(), len)];
            let total = collective.allreduce_mean_shards(&mut grads).unwrap();
            let local_loss = if collective.rank() == 0 { 1.25f32 } else { 3.5f32 };
            let loss = collective.allreduce_mean_scalar(local_loss, 1).unwrap();
            (total, grads.swap_remove(0), loss)
        },
    );
    for (total, grad, loss) in results {
        assert_eq!(total, 2);
        assert_bitwise(&grad, &expected, "collective gradient mean");
        assert_eq!(loss.to_bits(), expected_loss.to_bits(), "collective loss mean");
    }
}
