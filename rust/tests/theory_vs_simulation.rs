//! Theory ↔ simulation cross-checks at the paper's own toy scale
//! (m = n = 100, o = 30): the §5 closed forms must predict the measured
//! one-shot MSE of each estimator on problem (19).

use lowrank_sge::estimator::mse::{one_shot_mse, EstimatorSpec, MseCurveConfig};
use lowrank_sge::estimator::theory;
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::estimator::Family;
use lowrank_sge::linalg::sym_eig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;

fn cfg(family: Family, spec: EstimatorSpec, c: f64, r: usize) -> MseCurveConfig {
    MseCurveConfig {
        family,
        spec,
        c,
        r,
        sample_sizes: vec![1],
        reps: 1,
        seed: 314,
        zo_sigma: 1e-2,
        warmup: 400,
    }
}

#[test]
fn paper_scale_stiefel_matches_closed_form_ipa() {
    let p = ToyProblem::paper_default(1);
    let w = p.eval_point(2);
    let mut rng = Rng::new(3);
    let sxi = p.sigma_xi_empirical(&w, &mut rng, 1500, Family::Ipa, 1e-2);
    let sth = p.sigma_theta(&w);
    for &(c, r) in &[(1.0, 4usize), (0.5, 4), (1.0, 16)] {
        let predicted =
            theory::mse_isotropic_exact(p.n, r, c, sxi.trace(), sth.trace());
        let measured = one_shot_mse(
            &p,
            &w,
            &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), c, r),
            1200,
        );
        let rel = (measured - predicted).abs() / predicted;
        assert!(
            rel < 0.2,
            "c={c} r={r}: measured {measured:.3e} vs predicted {predicted:.3e} (rel {rel:.3})"
        );
    }
}

#[test]
fn paper_scale_gaussian_matches_wishart_form() {
    let p = ToyProblem::paper_default(5);
    let w = p.eval_point(6);
    let mut rng = Rng::new(7);
    let sxi = p.sigma_xi_empirical(&w, &mut rng, 1500, Family::Ipa, 1e-2);
    let sth = p.sigma_theta(&w);
    let (c, r) = (1.0, 4usize);
    let predicted = theory::mse_gaussian_exact(p.n, r, c, sxi.trace(), sth.trace());
    let measured = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Gaussian), c, r),
        1200,
    );
    let rel = (measured - predicted).abs() / predicted;
    assert!(
        rel < 0.25,
        "measured {measured:.3e} vs predicted {predicted:.3e} (rel {rel:.3})"
    );
}

#[test]
fn figure_ordering_full_vs_gaussian_vs_stiefel_vs_dependent() {
    // the Figures 2–5 method ordering at matched (c = 1, r = 4):
    //   Gaussian > Stiefel/Coordinate > Dependent (one-shot MSE).
    let p = ToyProblem::paper_default(9);
    let w = p.eval_point(10);
    let draws = 900;
    let m_g = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Gaussian), 1.0, 4),
        draws,
    );
    let m_s = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), 1.0, 4),
        draws,
    );
    let m_c = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Coordinate), 1.0, 4),
        draws,
    );
    let m_d = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Dependent), 1.0, 4),
        draws,
    );
    assert!(m_g > m_s, "gaussian {m_g:.3e} !> stiefel {m_s:.3e}");
    assert!(m_g > m_c, "gaussian {m_g:.3e} !> coordinate {m_c:.3e}");
    assert!(m_d < m_s, "dependent {m_d:.3e} !< stiefel {m_s:.3e}");
}

#[test]
fn dependent_mse_matches_thm3_value() {
    let p = ToyProblem::paper_default(11);
    let w = p.eval_point(12);
    let mut rng = Rng::new(13);
    let sigma = p.sigma_total(&w, &mut rng, 1500, Family::Ipa, 1e-2);
    let spec = sym_eig(&sigma).values;
    let sth = p.sigma_theta(&w);
    let (c, r) = (1.0, 8usize);
    let predicted = theory::mse_dependent_min(&spec, r, c, sth.trace());
    let measured = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Dependent), c, r),
        1200,
    );
    let rel = (measured - predicted).abs() / predicted.abs().max(1e-12);
    assert!(
        rel < 0.25,
        "measured {measured:.3e} vs Thm-3 value {predicted:.3e} (rel {rel:.3})"
    );
}

#[test]
fn lr_family_shows_same_ordering() {
    let p = ToyProblem::paper_default(15);
    let w = p.eval_point(16);
    let draws = 700;
    let m_g = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Lr, EstimatorSpec::LowRank(ProjectorKind::Gaussian), 1.0, 4),
        draws,
    );
    let m_s = one_shot_mse(
        &p,
        &w,
        &cfg(Family::Lr, EstimatorSpec::LowRank(ProjectorKind::Stiefel), 1.0, 4),
        draws,
    );
    assert!(
        m_g > m_s,
        "LR family: gaussian {m_g:.3e} should exceed stiefel {m_s:.3e}"
    );
}
