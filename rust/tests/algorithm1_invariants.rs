//! Algorithm-1 state-machine invariants over a real artifact manifest:
//! the resample/lift machinery must be exactly the paper's outer/inner
//! structure.

use lowrank_sge::coordinator::SubspaceSet;
use lowrank_sge::linalg::{matmul_nt, Mat};
use lowrank_sge::model::ParamStore;
use lowrank_sge::optim::AdamConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;
use lowrank_sge::runtime::ArtifactManifest;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn setup() -> Option<(ArtifactManifest, ParamStore)> {
    let dir = artifacts_dir();
    if !dir.join("INDEX.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = ArtifactManifest::load(&dir.join("lm_grad_s.manifest.txt")).unwrap();
    let store = ParamStore::load_init(&dir, "s", &manifest).unwrap();
    Some((manifest, store))
}

#[test]
fn subspace_covers_every_reparameterized_matrix() {
    let Some((manifest, store)) = setup() else { return };
    let sub = SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0,
        AdamConfig::default()).unwrap();
    // llama-s: 3 layers × 7 matrices
    assert_eq!(sub.slots.len(), 21);
    for slot in &sub.slots {
        assert_eq!(slot.r, 8);
        assert!(slot.m == 128 || slot.m == 384);
        assert!(slot.n == 128 || slot.n == 384);
        // dB output exists for the grad artifact
        assert_ne!(slot.db_output, usize::MAX, "{}", slot.name);
    }
    // B memory is Σ m·r ≪ Σ m·n (the Table-2 story)
    let full: usize = sub.slots.iter().map(|s| s.m * s.n).sum();
    let expect_b: usize = sub.slots.iter().map(|s| s.m * s.r).sum();
    assert_eq!(sub.b_elements(), expect_b);
    assert!(sub.b_elements() < full / 10);
    assert_eq!(sub.optimizer_state_bytes(), 8 * sub.b_elements());
}

#[test]
fn lift_with_zero_b_is_identity() {
    let Some((manifest, store)) = setup() else { return };
    let mut store = store;
    let before: Vec<Vec<f32>> = (0..store.len())
        .map(|i| store.f32(i).map(|s| s.to_vec()).unwrap_or_default())
        .collect();
    let mut sub = SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0,
        AdamConfig::default()).unwrap();
    let mut rng = Rng::new(1);
    sub.resample(&mut rng); // B = 0 after resample
    sub.lift(&mut store).unwrap();
    for i in 0..store.len() {
        if let Ok(after) = store.f32(i) {
            assert_eq!(after, before[i].as_slice(), "param {i} changed by zero lift");
        }
    }
}

#[test]
fn lift_matches_explicit_bvt_product() {
    let Some((manifest, store)) = setup() else { return };
    let mut store = store;
    let mut sub = SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Coordinate, 1.0,
        AdamConfig::default()).unwrap();
    let mut rng = Rng::new(2);
    sub.resample(&mut rng);
    // set B of slot 0 to something nonzero
    let (m, n, r) = (sub.slots[0].m, sub.slots[0].n, sub.slots[0].r);
    for (i, b) in std::sync::Arc::make_mut(&mut sub.slots[0].b).iter_mut().enumerate() {
        *b = (i as f32 * 0.01).sin();
    }
    let pos = sub.slots[0].param_pos;
    let theta_before = store.f32(pos).unwrap().to_vec();
    let b64 = Mat::from_fn(m, r, |i, j| sub.slots[0].b[i * r + j] as f64);
    let v64 = Mat::from_fn(n, r, |i, j| sub.slots[0].v[i * r + j] as f64);
    let delta = matmul_nt(&b64, &v64);
    sub.lift(&mut store).unwrap();
    let theta_after = store.f32(pos).unwrap();
    for i in 0..m * n {
        let want = theta_before[i] as f64 + delta.data[i];
        assert!((theta_after[i] as f64 - want).abs() < 1e-5, "lift mismatch at {i}");
    }
    // B zeroed after lift (Algorithm 1 line 3 of the next outer iter)
    assert!(sub.slots[0].b.iter().all(|&x| x == 0.0));
}

#[test]
fn resample_changes_v_and_counts_outer_iterations() {
    let Some((manifest, store)) = setup() else { return };
    let mut sub = SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0,
        AdamConfig::default()).unwrap();
    assert_eq!(sub.outer_iterations(), 0);
    let mut rng = Rng::new(3);
    sub.resample(&mut rng);
    let v1 = sub.slots[0].v.clone();
    sub.resample(&mut rng);
    let v2 = sub.slots[0].v.clone();
    assert_ne!(v1, v2, "resample produced identical V");
    assert_eq!(sub.outer_iterations(), 2);
}

#[test]
fn stiefel_v_gram_condition_survives_f32_roundtrip() {
    // Theorem 2's VᵀV = (cn/r)·I must hold (to f32 precision) on the
    // f32 V the artifact actually receives.
    let Some((manifest, store)) = setup() else { return };
    let mut sub = SubspaceSet::from_manifest(&manifest, &store, ProjectorKind::Stiefel, 1.0,
        AdamConfig::default()).unwrap();
    let mut rng = Rng::new(4);
    sub.resample(&mut rng);
    for slot in &sub.slots {
        let target = slot.n as f32 / slot.r as f32;
        for a in 0..slot.r {
            for b in 0..slot.r {
                let mut dot = 0.0f32;
                for i in 0..slot.n {
                    dot += slot.v[i * slot.r + a] * slot.v[i * slot.r + b];
                }
                let want = if a == b { target } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-3 * target,
                    "{}: VᵀV[{a},{b}] = {dot}, want {want}",
                    slot.name
                );
            }
        }
    }
}

#[test]
fn zo_manifest_maps_z_slots() {
    let dir = artifacts_dir();
    if !dir.join("INDEX.txt").exists() {
        return;
    }
    let manifest = ArtifactManifest::load(&dir.join("clf_zo_lowrank.manifest.txt")).unwrap();
    let store = ParamStore::load_init(&dir, "clf", &manifest).unwrap();
    let sub = SubspaceSet::from_zo_manifest(&manifest, &store, ProjectorKind::Gaussian, 1.0,
        AdamConfig::default()).unwrap();
    assert_eq!(sub.slots.len(), 21); // 3 layers × 7 matrices
    for slot in &sub.slots {
        assert_eq!(slot.db_output, usize::MAX); // ZO: no dB output
        assert_eq!(slot.r, 4);
    }
}
