//! Integration: the kernel substrate's determinism guarantee — parallel
//! results are bitwise identical to serial for every tested thread
//! count, on non-block-aligned (prime) shapes, in both precisions.
//!
//! These tests use explicit `KernelPool` instances (not the global
//! pool) so thread counts are exact and independent of the test
//! harness; CI additionally runs the whole suite under
//! `LOWRANK_THREADS=1` and `LOWRANK_THREADS=4` to catch any
//! thread-count dependence sneaking in through the global pool.

use lowrank_sge::coordinator::allreduce_mean_with;
use lowrank_sge::kernel::{self, KernelPool};

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn arb_f64(len: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(17);
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
        .collect()
}

fn arb_f32(len: usize, seed: u64) -> Vec<f32> {
    arb_f64(len, seed).into_iter().map(|x| x as f32).collect()
}

/// Prime dims: no shape is a multiple of the 32-row task block or the
/// 64-wide cache tile, so every partition boundary is ragged. Each
/// shape's m·k·n exceeds the kernel's small-GEMM inline threshold
/// (2¹⁶), so the parallel row-block path is genuinely exercised.
const SHAPES: [(usize, usize, usize); 3] = [(97, 53, 31), (131, 67, 17), (61, 37, 101)];

#[test]
fn gemm_nn_bitwise_across_thread_counts_f64() {
    for &(m, k, n) in &SHAPES {
        let a = arb_f64(m * k, 1);
        let b = arb_f64(k * n, 2);
        let mut reference = vec![0.0f64; m * n];
        kernel::serial::gemm_nn(&a, &b, &mut reference, m, k, n);
        for &threads in &THREAD_COUNTS {
            let pool = KernelPool::new(threads);
            let mut c = vec![0.0f64; m * n];
            kernel::gemm_nn(&pool, &a, &b, &mut c, m, k, n);
            for (x, y) in c.iter().zip(&reference) {
                assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n} threads={threads}");
            }
        }
    }
}

#[test]
fn gemm_nn_bitwise_across_thread_counts_f32() {
    for &(m, k, n) in &SHAPES {
        let a = arb_f32(m * k, 3);
        let b = arb_f32(k * n, 4);
        let mut reference = vec![0.0f32; m * n];
        kernel::serial::gemm_nn(&a, &b, &mut reference, m, k, n);
        for &threads in &THREAD_COUNTS {
            let pool = KernelPool::new(threads);
            let mut c = vec![0.0f32; m * n];
            kernel::gemm_nn(&pool, &a, &b, &mut c, m, k, n);
            for (x, y) in c.iter().zip(&reference) {
                assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n} threads={threads}");
            }
        }
    }
}

#[test]
fn gemm_tn_and_nt_bitwise_across_thread_counts() {
    let (m, k, n) = (101usize, 43usize, 29usize);
    // tn: A stored k×m
    let a_tn = arb_f64(k * m, 5);
    let b = arb_f64(k * n, 6);
    let mut ref_tn = vec![0.0f64; m * n];
    kernel::serial::gemm_tn(&a_tn, &b, &mut ref_tn, k, m, n);
    // nt: A m×k, B n×k, f32 with a non-trivial α
    let a_nt = arb_f32(m * k, 7);
    let b_nt = arb_f32(n * k, 8);
    let mut ref_nt = vec![0.0f32; m * n];
    kernel::serial::gemm_nt(0.37f32, &a_nt, &b_nt, &mut ref_nt, m, n, k);
    for &threads in &THREAD_COUNTS {
        let pool = KernelPool::new(threads);
        let mut c_tn = vec![0.0f64; m * n];
        kernel::gemm_tn(&pool, &a_tn, &b, &mut c_tn, k, m, n);
        let mut c_nt = vec![0.0f32; m * n];
        kernel::gemm_nt(&pool, 0.37f32, &a_nt, &b_nt, &mut c_nt, m, n, k);
        for i in 0..m * n {
            assert_eq!(c_tn[i].to_bits(), ref_tn[i].to_bits(), "tn threads={threads}");
            assert_eq!(c_nt[i].to_bits(), ref_nt[i].to_bits(), "nt threads={threads}");
        }
    }
}

#[test]
fn reductions_bitwise_across_thread_counts() {
    // long enough for many reduction chunks, prime length
    let len = 6 * kernel::REDUCE_CHUNK + 1009;
    let x = arb_f64(len, 9);
    let y = arb_f64(len, 10);
    let x32 = arb_f32(len, 11);
    let ref_dot = kernel::dot(&KernelPool::new(1), &x, &y);
    let ref_ssq = kernel::sum_sq(&KernelPool::new(1), &x32);
    for &threads in &THREAD_COUNTS {
        let pool = KernelPool::new(threads);
        assert_eq!(kernel::dot(&pool, &x, &y).to_bits(), ref_dot.to_bits());
        assert_eq!(kernel::sum_sq(&pool, &x32).to_bits(), ref_ssq.to_bits());
    }
}

#[test]
fn allreduce_bitwise_across_thread_counts() {
    // 5 workers (odd: ragged pairing tree) × prime-length f32 shards
    let workers = 5usize;
    let len = 40_961usize;
    let make = || -> Vec<Vec<f32>> {
        (0..workers).map(|w| arb_f32(len, 100 + w as u64)).collect()
    };
    let mut reference = make();
    let n = allreduce_mean_with(&KernelPool::new(1), &mut reference);
    assert_eq!(n, workers);
    for &threads in &THREAD_COUNTS {
        let pool = KernelPool::new(threads);
        let mut grads = make();
        allreduce_mean_with(&pool, &mut grads);
        for (x, y) in grads[0].iter().zip(&reference[0]) {
            assert_eq!(x.to_bits(), y.to_bits(), "allreduce threads={threads}");
        }
    }
    // sanity: it really is the mean
    let grads = make();
    let manual: f32 = (0..workers).map(|w| grads[w][17]).sum::<f32>() / workers as f32;
    assert!((reference[0][17] - manual).abs() < 1e-6);
}

#[test]
fn linalg_mat_ops_bitwise_across_global_thread_counts() {
    // The f64 Mat API rides the *global* pool; swap its size and check
    // the high-level results stay identical. (The global pool is also
    // what LOWRANK_THREADS steers in CI.)
    use lowrank_sge::linalg::{matmul, matmul_nt, matmul_tn, Mat};
    let a = Mat::from_fn(67, 41, |i, j| ((i * 41 + j) as f64 * 0.619).sin());
    let b = Mat::from_fn(41, 53, |i, j| ((i * 53 + j) as f64 * 0.377).cos());
    let c = Mat::from_fn(29, 41, |i, j| ((i + 2 * j) as f64 * 0.211).sin());
    let mut snapshots = Vec::new();
    for &threads in &[1usize, 4] {
        kernel::set_global_threads(threads);
        let p1 = matmul(&a, &b);
        let p2 = matmul_tn(&a, &p1); // 41×53
        let p3 = matmul_nt(&a, &c); // 67×29
        snapshots.push((p1, p2, p3));
    }
    let (r1, r2, r3) = &snapshots[0];
    let (s1, s2, s3) = &snapshots[1];
    for (x, y) in r1.data.iter().zip(&s1.data) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in r2.data.iter().zip(&s2.data) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in r3.data.iter().zip(&s3.data) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
