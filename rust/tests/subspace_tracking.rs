//! Warm-started subspace tracking + adaptive per-layer rank:
//! integration pins for the amortized lazy-update boundary.
//!
//! * Every tracked refresh must preserve the Theorem-2 frame property
//!   (QᵀQ = I at f64, VᵀV = (c·n/r)·I at f32).
//! * The tracked trajectory is thread-count invariant (one forked child
//!   stream per slot, pool size is timing only) — CI drives this test
//!   binary across `LOWRANK_TRACK_REFRESH` ∈ {0, 4} ×
//!   `LOWRANK_THREADS` ∈ {1, 4}.
//! * `--track-refresh 1` degenerates to the classic fresh-draw
//!   trajectory bit for bit.
//! * (artifact-gated) With tracking *and* a shrink-happy rank
//!   controller on, train(2k) ≡ train(k) → save → resume → train(k)
//!   bitwise, at 1 and 4 threads.
//! * (artifact-gated) A 2-rank `launch pretrain --rank-adapt` world
//!   takes identical per-slot rank decisions on every rank.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Mutex, MutexGuard, OnceLock};

use lowrank_sge::bench_util::engine_fixture;
use lowrank_sge::ckpt::{CkptOptions, ResumeSpec};
use lowrank_sge::coordinator::{PretrainConfig, PretrainTrainer, SubspaceSet};
use lowrank_sge::optim::RankAdaptConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;
use lowrank_sge::runtime::Runtime;

const BIN: &str = env!("CARGO_BIN_EXE_lowrank-sge");

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("INDEX.txt").exists()
}

/// Tests that resize the global kernel pool (directly or through
/// `cfg.threads`) serialize here so they cannot race each other's
/// resize/restore cycle — results are pool-size invariant either way,
/// this only keeps the restore bookkeeping sane.
fn pool_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// The CI matrix knob: tracked-refresh period for the trajectory tests
/// (0 = fresh draw every resample, the untracked baseline leg).
fn track_refresh_env() -> u64 {
    std::env::var("LOWRANK_TRACK_REFRESH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

const DIMS: [(usize, usize, usize); 3] = [(48, 40, 6), (40, 40, 4), (64, 24, 5)];

fn tracked_set(refresh: u64) -> (lowrank_sge::model::ParamStore, SubspaceSet) {
    let (store, slots) = engine_fixture(&DIMS, 16);
    let mut set = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    set.set_tracking(refresh);
    (store, set)
}

#[test]
fn tracked_updates_preserve_the_stiefel_frame_gram() {
    let (_store, mut set) = tracked_set(4);
    let mut rng = Rng::new(314);
    for resample in 0..6 {
        set.resample(&mut rng);
        for slot in &set.slots {
            let (n, r) = (slot.n, slot.r);
            // f64 frame: QᵀQ = I to 1e-6 after every tracked update
            let q = &slot.frame.as_ref().expect("tracking stores a frame").data;
            assert_eq!(q.len(), n * r);
            for i in 0..r {
                for j in 0..r {
                    let dot: f64 = (0..n).map(|k| q[k * r + i] * q[k * r + j]).sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (dot - want).abs() <= 1e-6,
                        "resample {resample} slot {}: QᵀQ[{i},{j}] = {dot}",
                        slot.name
                    );
                }
            }
            // f32 V = √(c·n/r)·Q: VᵀV = (c·n/r)·I up to the f32 cast
            let scale = n as f64 / r as f64;
            for i in 0..r {
                let dot: f64 =
                    (0..n).map(|k| slot.v[k * r + i] as f64 * slot.v[k * r + i] as f64).sum();
                assert!(
                    (dot / scale - 1.0).abs() <= 1e-4,
                    "resample {resample} slot {}: VᵀV[{i},{i}]/α² = {}",
                    slot.name,
                    dot / scale
                );
            }
        }
    }
}

#[test]
fn track_refresh_one_matches_fresh_draws_bitwise() {
    // T = 1 means every resample is a full redraw through the tracked
    // path — it must reproduce the classic sampler's bits exactly
    let (_sa, mut fresh) = tracked_set(0);
    let (_sb, mut tracked) = tracked_set(1);
    let mut rng_a = Rng::new(99);
    let mut rng_b = Rng::new(99);
    for round in 0..3 {
        fresh.resample(&mut rng_a);
        tracked.resample(&mut rng_b);
        for (a, b) in fresh.slots.iter().zip(&tracked.slots) {
            for (x, y) in a.v.iter().zip(b.v.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "round {round} slot {} diverged", a.name);
            }
        }
    }
}

/// Drive resample → per-slot Adam steps → lift (with a mid-run shrink)
/// at a given pool size; return every live bit the trajectory owns.
fn run_tracked_trajectory(threads: usize, refresh: u64) -> Vec<u32> {
    lowrank_sge::kernel::set_global_threads(threads);
    let (mut store, mut set) = tracked_set(refresh);
    let mut rng = Rng::new(2718);
    for outer in 0..4u64 {
        set.resample(&mut rng);
        for step in 0..2u64 {
            let grads: Vec<Vec<f32>> = set
                .slots
                .iter()
                .enumerate()
                .map(|(si, s)| {
                    (0..s.m * s.r)
                        .map(|i| {
                            (((outer * 100 + step * 17 + si as u64 * 5 + i as u64) as f32) * 0.01)
                                .sin()
                        })
                        .collect()
                })
                .collect();
            set.adam_step_all(&grads, 1e-2);
        }
        set.lift(&mut store).unwrap();
        if outer == 1 {
            // exercise the shrink re-layout inside the tracked schedule
            set.shrink_slot_rank(0, 3).unwrap();
        }
    }
    let mut bits = Vec::new();
    for i in 0..store.len() {
        bits.extend(store.f32(i).unwrap().iter().map(|v| v.to_bits()));
    }
    for slot in &set.slots {
        bits.extend(slot.v.iter().map(|v| v.to_bits()));
        if let Some(f) = &slot.frame {
            bits.extend(f.data.iter().flat_map(|v| {
                let b = v.to_bits();
                [(b >> 32) as u32, b as u32]
            }));
        }
    }
    bits
}

#[test]
fn tracked_trajectory_is_thread_count_invariant() {
    let _lock = pool_lock();
    let prev = lowrank_sge::kernel::global_threads();
    let refresh = track_refresh_env();
    let serial = run_tracked_trajectory(1, refresh);
    let parallel = run_tracked_trajectory(4, refresh);
    lowrank_sge::kernel::set_global_threads(prev);
    assert!(!serial.is_empty());
    assert_eq!(serial, parallel, "tracked trajectory diverged across thread counts");
}

fn forced_adapt() -> RankAdaptConfig {
    // window 2 + decay 10 make every completed window shrink (while
    // target < r), so the resume crosses real rank re-layouts
    RankAdaptConfig { min_rank: 2, window: 2, decay: 10.0, factor: 0.75 }
}

#[test]
fn tracked_rank_adapt_resume_reproduces_uninterrupted_run() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let _lock = pool_lock();
    let prev = lowrank_sge::kernel::global_threads();
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut per_thread_bits: Vec<Vec<u32>> = Vec::new();

    for threads in [1usize, 4] {
        let base = {
            let mut cfg = PretrainConfig::quick("s", ProjectorKind::Stiefel);
            cfg.steps = 10;
            cfg.k_interval = 3; // boundaries at 3, 6, 9; save at 5 is mid-window
            cfg.eval_every = 0;
            cfg.workers = 1;
            cfg.threads = threads;
            cfg.track_refresh = 2;
            cfg.rank_adapt = Some(forced_adapt());
            cfg
        };
        let ckpt_dir = std::env::temp_dir().join(format!(
            "lowrank_sge_tracking_resume_p{}_t{threads}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&ckpt_dir);

        // uninterrupted reference
        let mut reference = PretrainTrainer::new(&mut rt, &dir, base.clone()).unwrap();
        let ref_res = reference.run().unwrap();

        // interrupted at step 5 (mid-outer, mid-controller-window) …
        let mut cfg_a = base.clone();
        cfg_a.steps = 5;
        cfg_a.ckpt =
            CkptOptions { save_every: 5, dir: Some(ckpt_dir.clone()), resume: None, keep_last: 0 };
        let res1 = PretrainTrainer::new(&mut rt, &dir, cfg_a).unwrap().run().unwrap();

        // … resumed from LATEST: tracked frames, ranks, controller
        // history, and Adam moments all come back from the checkpoint
        let mut cfg_b = base.clone();
        cfg_b.ckpt = CkptOptions {
            save_every: 0,
            dir: Some(ckpt_dir.clone()),
            resume: Some(ResumeSpec::Latest),
            keep_last: 0,
        };
        let mut resumed = PretrainTrainer::new(&mut rt, &dir, cfg_b).unwrap();
        let res2 = resumed.run().unwrap();
        let _ = std::fs::remove_dir_all(&ckpt_dir);

        assert_eq!(res1.log.records.len(), 5);
        assert_eq!(res2.log.records.len(), 5);
        for (r, s) in ref_res.log.records[..5].iter().zip(&res1.log.records) {
            assert_eq!(r.loss.to_bits(), s.loss.to_bits(), "t{threads} pre-save step {}", r.step);
        }
        for (r, s) in ref_res.log.records[5..].iter().zip(&res2.log.records) {
            assert_eq!(r.loss.to_bits(), s.loss.to_bits(), "t{threads} resumed step {}", r.step);
        }
        let mut bits = Vec::new();
        for i in 0..reference.store().len() {
            let a = reference.store().f32(i).unwrap();
            let b = resumed.store().f32(i).unwrap();
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "t{threads} param {i} diverged on resume");
            }
            bits.extend(a.iter().map(|v| v.to_bits()));
        }
        per_thread_bits.push(bits);
        // the forced controller must actually have shrunk ranks: the
        // final subspace footprint sits below the manifest footprint
        // the same run reports without adaptation
        if threads == 1 {
            let mut cfg_fixed = base.clone();
            cfg_fixed.rank_adapt = None;
            let fixed = PretrainTrainer::new(&mut rt, &dir, cfg_fixed).unwrap().run().unwrap();
            assert!(
                ref_res.b_elements < fixed.b_elements,
                "rank controller never shrank: {} vs {}",
                ref_res.b_elements,
                fixed.b_elements
            );
        }
    }
    lowrank_sge::kernel::set_global_threads(prev);
    // … and the whole trained trajectory is thread-count invariant
    assert_eq!(per_thread_bits[0], per_thread_bits[1], "trained bytes diverged across threads");
}

#[test]
fn launch_two_ranks_take_identical_rank_decisions() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = Command::new(BIN)
        .args([
            "launch",
            "--nproc",
            "2",
            "pretrain",
            "--scale",
            "s",
            "--steps",
            "6",
            "--k",
            "2",
            "--workers",
            "2",
            "--seed",
            "33",
            "--eval-every",
            "0",
            "--track-refresh",
            "2",
            "--rank-adapt",
            "--rank-window",
            "2",
            "--rank-decay",
            "10",
        ])
        .env("LOWRANK_SGE_ARTIFACTS", artifacts_dir())
        // decision identity is asserted on the f32 lane, like the
        // checkpoint-bitwise launch contract
        .env("LOWRANK_COMM_DTYPE", "f32")
        .output()
        .expect("running the launch binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "launch failed\nstdout:\n{stdout}\nstderr:\n{stderr}");
    // every rank prints its own decision lines; the multisets (here:
    // sorted lists) must agree exactly, slot for slot
    let decisions = |rank: usize| -> Vec<String> {
        let tag = format!("[rank-adapt r{rank}] ");
        let mut v: Vec<String> = stdout
            .lines()
            .filter_map(|l| l.find(&tag).map(|p| l[p + tag.len()..].to_string()))
            .collect();
        v.sort();
        v
    };
    let (d0, d1) = (decisions(0), decisions(1));
    assert!(!d0.is_empty(), "rank 0 took no rank decisions\nstdout:\n{stdout}");
    assert_eq!(d0, d1, "ranks took different rank decisions\nstdout:\n{stdout}");
    assert!(
        d0.iter().any(|l| l.contains("shrink")),
        "forced controller never shrank\nstdout:\n{stdout}"
    );
}
