//! End-to-end trainer integration: Algorithm 1 over real PJRT artifacts
//! must reduce the LM loss, and the fine-tuning methods must beat chance
//! on an easy task. These are short smoke-scale runs; the full
//! experiments live in `lowrank-sge exp …`.

use lowrank_sge::ckpt::{CkptOptions, ResumeSpec};
use lowrank_sge::coordinator::{
    FinetuneConfig, FinetuneMethod, FinetuneTrainer, PretrainConfig, PretrainTrainer,
};
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("INDEX.txt").exists()
}

#[test]
fn pretrain_stiefel_reduces_loss() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut cfg = PretrainConfig::quick("s", ProjectorKind::Stiefel);
    cfg.steps = 24;
    cfg.k_interval = 6;
    cfg.eval_every = 12;
    cfg.eval_batches = 1;
    cfg.lr = 3e-3;
    let mut trainer = PretrainTrainer::new(&mut rt, &dir, cfg).unwrap();
    let res = trainer.run().unwrap();
    assert_eq!(res.log.records.len(), 24);
    let first = res.log.records[0].loss;
    let tail = res.log.tail_mean_loss(4).unwrap();
    assert!(
        tail < first - 0.2,
        "loss did not decrease: first {first}, tail {tail}"
    );
    // memory story: subspace B is far smaller than the full matrices
    assert!(res.b_elements * 4 < res.params_elements);
    // evals were recorded and finite
    assert_eq!(res.log.evals.len(), 2);
    assert!(res.log.evals.iter().all(|(_, v)| v.is_finite()));
}

#[test]
fn pretrain_ddp_two_workers_runs() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut cfg = PretrainConfig::quick("s", ProjectorKind::Gaussian);
    cfg.steps = 6;
    cfg.k_interval = 3;
    cfg.workers = 2;
    cfg.eval_every = 0;
    let mut trainer = PretrainTrainer::new(&mut rt, &dir, cfg).unwrap();
    let res = trainer.run().unwrap();
    assert_eq!(res.log.records.len(), 6);
    assert!(res.log.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn pretrain_resume_reproduces_uninterrupted_run_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let ckpt_dir = std::env::temp_dir().join("lowrank_sge_e2e_pretrain_resume");
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    let base = {
        let mut cfg = PretrainConfig::quick("s", ProjectorKind::Stiefel);
        cfg.steps = 12;
        cfg.k_interval = 5; // step 6 sits mid-outer-iteration
        cfg.eval_every = 0;
        cfg.workers = 1; // single worker ⇒ deterministic shard order
        cfg
    };

    // uninterrupted reference
    let mut rt = Runtime::new(&dir).unwrap();
    let mut trainer = PretrainTrainer::new(&mut rt, &dir, base.clone()).unwrap();
    let reference = trainer.run().unwrap();

    // interrupted at step 6 …
    let mut cfg_a = base.clone();
    cfg_a.steps = 6;
    cfg_a.ckpt =
        CkptOptions { save_every: 6, dir: Some(ckpt_dir.clone()), resume: None, keep_last: 0 };
    let mut part1 = PretrainTrainer::new(&mut rt, &dir, cfg_a).unwrap();
    let res1 = part1.run().unwrap();
    drop(part1);

    // … resumed from LATEST in a fresh trainer
    let mut cfg_b = base.clone();
    cfg_b.ckpt = CkptOptions {
        save_every: 0,
        dir: Some(ckpt_dir.clone()),
        resume: Some(ResumeSpec::Latest),
        keep_last: 0,
    };
    let mut part2 = PretrainTrainer::new(&mut rt, &dir, cfg_b).unwrap();
    let res2 = part2.run().unwrap();

    assert_eq!(res1.log.records.len(), 6);
    assert_eq!(res2.log.records.len(), 6);
    assert_eq!(res2.log.records[0].step, 6);
    for (r, s) in reference.log.records[..6].iter().zip(&res1.log.records) {
        assert_eq!(r.loss.to_bits(), s.loss.to_bits(), "pre-save step {} diverged", r.step);
    }
    for (r, s) in reference.log.records[6..].iter().zip(&res2.log.records) {
        assert_eq!(
            r.loss.to_bits(),
            s.loss.to_bits(),
            "resumed step {} diverged: {} vs {}",
            r.step,
            r.loss,
            s.loss
        );
    }
    // final lifted parameters agree bitwise
    for i in 0..trainer.store().len() {
        let a = trainer.store().f32(i).unwrap();
        let b = part2.store().f32(i).unwrap();
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "param {i} diverged");
        }
    }
}

#[test]
fn finetune_resume_reproduces_uninterrupted_run_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let ckpt_dir = std::env::temp_dir().join("lowrank_sge_e2e_finetune_resume");
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    let method = FinetuneMethod::LowRankIpa(ProjectorKind::Stiefel);
    let base = {
        let mut cfg = FinetuneConfig::quick("sst2", method);
        cfg.steps = 20;
        cfg.k_interval = 8; // save at 10 is mid-outer-iteration
        cfg
    };

    let mut rt = Runtime::new(&dir).unwrap();
    let reference = FinetuneTrainer::new(&mut rt, &dir, base.clone()).unwrap().run().unwrap();

    let mut cfg_a = base.clone();
    cfg_a.steps = 10;
    cfg_a.ckpt =
        CkptOptions { save_every: 10, dir: Some(ckpt_dir.clone()), resume: None, keep_last: 0 };
    let res1 = FinetuneTrainer::new(&mut rt, &dir, cfg_a).unwrap().run().unwrap();

    let mut cfg_b = base.clone();
    cfg_b.ckpt = CkptOptions {
        save_every: 0,
        dir: Some(ckpt_dir.clone()),
        resume: Some(ResumeSpec::Latest),
        keep_last: 0,
    };
    let res2 = FinetuneTrainer::new(&mut rt, &dir, cfg_b).unwrap().run().unwrap();

    for (r, s) in reference.log.records[..10].iter().zip(&res1.log.records) {
        assert_eq!(r.loss.to_bits(), s.loss.to_bits(), "pre-save step {} diverged", r.step);
    }
    for (r, s) in reference.log.records[10..].iter().zip(&res2.log.records) {
        assert_eq!(r.loss.to_bits(), s.loss.to_bits(), "resumed step {} diverged", r.step);
    }
    // the final eval accuracy is a function of the final Θ: must match
    assert_eq!(reference.accuracy, res2.accuracy);

    // resuming under the wrong method is rejected up front
    let mut cfg_bad = base;
    cfg_bad.method = FinetuneMethod::VanillaIpa;
    cfg_bad.ckpt = CkptOptions {
        save_every: 0,
        dir: Some(ckpt_dir),
        resume: Some(ResumeSpec::Latest),
        keep_last: 0,
    };
    assert!(FinetuneTrainer::new(&mut rt, &dir, cfg_bad).unwrap().run().is_err());
}

#[test]
fn finetune_vanilla_ipa_beats_chance_on_easy_task() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut cfg = FinetuneConfig::quick("trec", FinetuneMethod::VanillaIpa);
    cfg.steps = 80;
    cfg.ipa_lr = 1e-3;
    let mut t = FinetuneTrainer::new(&mut rt, &dir, cfg).unwrap();
    let res = t.run().unwrap();
    // trec has 6 classes → chance ≈ 0.167
    assert!(
        res.accuracy > 0.35,
        "vanilla IPA accuracy {} not above chance",
        res.accuracy
    );
    // loss decreased
    let first = res.log.records[0].loss;
    let tail = res.log.tail_mean_loss(8).unwrap();
    assert!(tail < first, "loss: first {first}, tail {tail}");
}

#[test]
fn finetune_zo_methods_run_and_stay_finite() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    for method in [
        FinetuneMethod::VanillaLr,
        FinetuneMethod::LowRankLr(ProjectorKind::Stiefel),
    ] {
        let mut cfg = FinetuneConfig::quick("sst2", method);
        cfg.steps = 30;
        cfg.k_interval = 10;
        let mut t = FinetuneTrainer::new(&mut rt, &dir, cfg).unwrap();
        let res = t.run().unwrap();
        assert!(res.accuracy.is_finite() && res.accuracy > 0.0);
        assert!(res.log.records.iter().all(|r| r.loss.is_finite()));
    }
}

#[test]
fn finetune_zero_shot_is_near_chance() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let cfg = FinetuneConfig::quick("sst2", FinetuneMethod::ZeroShot);
    let mut t = FinetuneTrainer::new(&mut rt, &dir, cfg).unwrap();
    let res = t.run().unwrap();
    // The classifier head has 8 logits (padded class space) but sst2
    // uses only 2 labels, so an untrained argmax mostly lands on unused
    // classes: zero-shot accuracy is *below* 2-class chance. Anything
    // well under the trained accuracies (and above exactly 0) is sane.
    assert!(
        res.accuracy > 0.0 && res.accuracy < 0.55,
        "zero-shot accuracy {} out of band",
        res.accuracy
    );
    assert!(res.log.records.is_empty());
}

#[test]
fn lowrank_ipa_finetune_lifts_and_improves() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut cfg = FinetuneConfig::quick("trec", FinetuneMethod::LowRankIpa(ProjectorKind::Stiefel));
    cfg.steps = 80;
    cfg.k_interval = 20;
    cfg.ipa_lr = 2e-3;
    let mut t = FinetuneTrainer::new(&mut rt, &dir, cfg).unwrap();
    let res = t.run().unwrap();
    assert!(
        res.accuracy > 0.3,
        "lowrank-IPA accuracy {} not above chance",
        res.accuracy
    );
}
