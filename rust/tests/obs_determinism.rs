//! The observability non-perturbation contract: turning tracing and
//! metrics on must not change a single trained bit. The LowRank-LR
//! engine loop (the same fixture as `tests/engine_alloc.rs`) runs once
//! with the subsystem off and once with spans + metrics + monitor
//! watermark stamps fully on, at thread counts 1 and 4; the resulting
//! ParamStore must be bitwise identical. The same contract extended to
//! the estimator-quality probe steps is pinned by
//! `tests/obs_monitor.rs`. The two tests here share one lock because
//! they both toggle the process-global enabled flags.

use std::sync::Mutex;

use lowrank_sge::bench_util::engine_fixture;
use lowrank_sge::coordinator::SubspaceSet;
use lowrank_sge::estimator::engine::{GradEstimator, GradSignal, MethodShape};
use lowrank_sge::model::ParamStore;
use lowrank_sge::obs;
use lowrank_sge::optim::AdamConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;

static TEST_LOCK: Mutex<()> = Mutex::new(());

const DIMS: [(usize, usize, usize); 3] = [(48, 32, 4), (32, 32, 2), (40, 24, 8)];
const HEAD_LEN: usize = 24;
const STEPS: u64 = 23;

/// One full fixture run: fresh store/engine/RNG, `STEPS` LowRank-LR
/// steps (with resamples mid-run via a fresh subspace draw), returning
/// every parameter byte.
fn run_fixture(threads: usize) -> Vec<u8> {
    lowrank_sge::kernel::set_global_threads(threads);
    let (mut store, slots) = engine_fixture(&DIMS, HEAD_LEN);
    let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    let mut engine = GradEstimator::new(
        MethodShape::LowRankLr,
        1e-2,
        Some(sub),
        Vec::new(),
        Vec::new(),
        Some((DIMS.len(), HEAD_LEN, AdamConfig::default())),
    );
    let mut rng = Rng::new(7);
    engine.subspace.as_mut().unwrap().resample(&mut rng);
    for step in 0..STEPS {
        if step == 11 {
            // exercise the resample path (spanned in the trainers) too
            engine.subspace.as_mut().unwrap().resample(&mut rng);
            obs::monitor::stamp(obs::monitor::Phase::Resample, step);
        }
        obs::monitor::stamp(obs::monitor::Phase::Execute, step);
        engine.draw_perturbations(&mut rng);
        let fp = 0.8 + (step as f32) * 0.003;
        let fm = 0.7 - (step as f32) * 0.002;
        engine
            .step(&mut store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, 1e-3)
            .unwrap();
        obs::monitor::stamp(obs::monitor::Phase::Update, step);
    }
    store_bytes(&store)
}

fn store_bytes(store: &ParamStore) -> Vec<u8> {
    let mut out = Vec::new();
    for i in 0..store.len() {
        for v in store.f32(i).unwrap() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

#[test]
fn trained_bits_are_identical_with_obs_on_and_off() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for threads in [1usize, 4] {
        obs::span::set_enabled(false);
        obs::metrics::set_enabled(false);
        obs::monitor::set_enabled(false);
        let off = run_fixture(threads);

        obs::span::set_enabled(true);
        obs::metrics::set_enabled(true);
        obs::monitor::set_enabled(true);
        let on = run_fixture(threads);

        // leave the process flags off for any later assertions
        obs::span::set_enabled(false);
        obs::metrics::set_enabled(false);
        obs::monitor::set_enabled(false);

        // assert! (not assert_eq!) so a failure doesn't dump every byte
        assert!(
            off == on,
            "observability perturbed the trained bytes at {threads} thread(s)"
        );
        assert!(!off.is_empty() && off.iter().any(|&b| b != 0));
    }
}

#[test]
fn traced_run_exports_valid_chrome_json() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::span::set_enabled(true);
    obs::metrics::set_enabled(true);
    let _ = run_fixture(2);
    obs::metrics::record_value("test.series", 1.25);

    let dir = std::env::temp_dir().join("lowrank_sge_obs_determinism");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let n = obs::span::write_chrome_trace(&path, 0).unwrap();
    obs::span::set_enabled(false);
    obs::metrics::set_enabled(false);

    assert!(n > 0, "a traced engine run must record spans");
    let text = std::fs::read_to_string(&path).unwrap();
    // bare JSON array of event objects with the Chrome trace_event keys
    assert!(text.trim_start().starts_with('[') && text.trim_end().ends_with(']'), "{text}");
    assert!(text.contains("\"ph\":\"X\"") && text.contains("\"cat\":\"engine\""), "{text}");
    // balanced delimiters outside strings — the same light-weight JSON
    // check the span unit tests use
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in text.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => depth -= 1,
            _ => {}
        }
    }
    assert_eq!(depth, 0, "unbalanced JSON in exported trace");
    assert!(!in_str);

    // the metrics snapshot of the same run is one parseable JSON line
    let snap = obs::metrics::snapshot_json(0);
    assert!(snap.starts_with('{') && snap.ends_with('}'), "{snap}");
    assert!(obs::metrics::json_u64(&snap, "kernel.pool_tasks").is_some(), "{snap}");
}
