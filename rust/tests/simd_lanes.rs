//! Integration: the fixed-lane SIMD contract — every kernel result is
//! bitwise identical under `LOWRANK_SIMD=scalar` (the portable lane
//! emulation) and `LOWRANK_SIMD=auto` (AVX/NEON tiles), across ragged
//! tails, prime shapes, NaN/Inf payloads, both precisions, and thread
//! counts. The scalar emulation *is* the definition of the canonical
//! accumulation order; the vector backends must reproduce it exactly.
//!
//! The mode is flipped in-process via [`simd::set_mode`] (the same
//! switch the benches use), serialized by a binary-local mutex around
//! the process-global mode word. CI additionally runs this whole suite
//! under both `LOWRANK_SIMD` values × `LOWRANK_THREADS` ∈ {1, 4}.

use std::sync::Mutex;

use lowrank_sge::kernel::simd::{self, SimdMode};
use lowrank_sge::kernel::{self, KernelPool};

static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under both modes and assert the collected bit patterns are
/// identical. The previous mode is restored afterwards, so tests that
/// share the binary (and CI's env-driven runs) see their own setting.
fn assert_modes_agree(ctx: &str, f: impl Fn() -> Vec<u64>) {
    let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = simd::mode();
    simd::set_mode(SimdMode::Scalar);
    let emulated = f();
    simd::set_mode(SimdMode::Auto);
    let backend = simd::active_backend();
    let dispatched = f();
    simd::set_mode(prev);
    assert_eq!(emulated.len(), dispatched.len(), "{ctx}");
    for (i, (e, d)) in emulated.iter().zip(&dispatched).enumerate() {
        assert_eq!(e, d, "{ctx}: scalar-emulation vs {backend} backend differ at element {i}");
    }
}

fn arb_f64(len: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(17);
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
        .collect()
}

fn arb_f32(len: usize, seed: u64) -> Vec<f32> {
    arb_f64(len, seed).into_iter().map(|x| x as f32).collect()
}

#[test]
fn lane_dot_bitwise_across_backends_every_tail_length() {
    // every tail residue 0..8 (f32) / 0..4 (f64), plus lengths long
    // enough to cross the reduction-chunk boundary
    let lens: Vec<usize> =
        (0..=33).chain([61, 1009, 3 * kernel::REDUCE_CHUNK + 5]).collect();
    for &len in &lens {
        let x64 = arb_f64(len, 2 * len as u64 + 1);
        let y64 = arb_f64(len, 2 * len as u64 + 2);
        let x32 = arb_f32(len, 2 * len as u64 + 3);
        let y32 = arb_f32(len, 2 * len as u64 + 4);
        assert_modes_agree(&format!("lane_dot len={len}"), || {
            vec![
                kernel::lane_dot(&x64, &y64).to_bits(),
                kernel::lane_dot(&x32, &y32).to_bits() as u64,
            ]
        });
        // in every mode the result IS the portable lane emulation
        let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(
            kernel::lane_dot(&x64, &y64).to_bits(),
            simd::lane_dot_scalar(&x64, &y64).to_bits(),
            "len={len}: lane_dot must equal its scalar definition"
        );
    }
}

#[test]
fn gemm_bitwise_across_backends_and_threads() {
    // prime dims: every row block and cache tile boundary is ragged
    for &(m, k, n) in &[(97usize, 53usize, 31usize), (61, 37, 101)] {
        let a64 = arb_f64(m * k, 11);
        let b64 = arb_f64(n * k, 12);
        let a32 = arb_f32(m * k, 13);
        let b32 = arb_f32(n * k, 14);
        let bnn32 = arb_f32(k * n, 15);
        for threads in [1usize, 4] {
            let pool = KernelPool::new(threads);
            assert_modes_agree(&format!("gemm {m}x{k}x{n} threads={threads}"), || {
                let mut c64 = vec![0.0f64; m * n];
                kernel::gemm_nt(&pool, 0.37f64, &a64, &b64, &mut c64, m, n, k);
                let mut c32 = vec![0.0f32; m * n];
                kernel::gemm_nt(&pool, 0.37f32, &a32, &b32, &mut c32, m, n, k);
                let mut cnn = vec![0.0f32; m * n];
                kernel::gemm_nn(&pool, &a32, &bnn32, &mut cnn, m, k, n);
                c64.iter()
                    .map(|x| x.to_bits())
                    .chain(c32.iter().map(|x| x.to_bits() as u64))
                    .chain(cnn.iter().map(|x| x.to_bits() as u64))
                    .collect()
            });
        }
    }
}

#[test]
fn element_ops_and_reductions_bitwise_across_backends() {
    let len = 4099usize; // prime: ragged vector tail everywhere
    let x64 = arb_f64(len, 21);
    let x32 = arb_f32(len, 22);
    let y32 = arb_f32(len, 23);
    for threads in [1usize, 4] {
        let pool = KernelPool::new(threads);
        assert_modes_agree(&format!("elem/reduce threads={threads}"), || {
            let mut acc = y32.clone();
            kernel::axpy(&pool, 0.73f32, &x32, &mut acc);
            kernel::scale(&pool, &mut acc, 1.0f32 / 3.0);
            kernel::add_assign(&pool, &mut acc, &y32);
            let mut bits: Vec<u64> = acc.iter().map(|v| v.to_bits() as u64).collect();
            bits.push(kernel::dot(&pool, &x64, &x64).to_bits());
            bits.push(kernel::sum_sq(&pool, &x32).to_bits());
            bits
        });
    }
}

#[test]
fn nan_inf_and_signed_zero_payloads_identical_across_backends() {
    // specials in every lane position of the first vector block and in
    // the ragged tail; products like 0·∞ and NaN payload propagation
    // must come out of the vector tiles exactly as from the emulation
    let len = 29usize;
    let mut x = arb_f32(len, 31);
    let y = arb_f32(len, 32);
    x[0] = f32::NAN;
    x[3] = f32::INFINITY;
    x[5] = f32::NEG_INFINITY;
    x[7] = -0.0;
    x[11] = f32::from_bits(0x7FC0_1234); // NaN with payload
    x[26] = f32::NAN; // in the tail
    x[28] = f32::INFINITY;
    let mut x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    x64[2] = f64::NAN;
    let y64 = arb_f64(len, 33);
    assert_modes_agree("special values", || {
        // 1×len×1 gemm_nt: C[0][j] = x[0]·y[j], with x[0] = NaN
        let mut acc = vec![0.0f32; len];
        kernel::serial::gemm_nt(1.0f32, &x[..1], &y, &mut acc, 1, len, 1);
        let mut bits: Vec<u64> =
            vec![kernel::lane_dot(&x, &y).to_bits() as u64, kernel::lane_dot(&x64, &y64).to_bits()];
        bits.extend(acc.iter().map(|v| v.to_bits() as u64));
        let mut fma = y.clone();
        lowrank_sge::kernel::Scalar::fma_row(&mut fma[..], x[11], &x);
        bits.extend(fma.iter().map(|v| v.to_bits() as u64));
        bits
    });
}

#[test]
fn bf16_batch_kernels_bitwise_across_backends() {
    // every length 0..=64 (all AVX2 block tails) + RNE ties + specials
    for len in 0..=64usize {
        let mut src = arb_f32(len, 41 + len as u64);
        if len > 4 {
            src[1] = f32::from_bits(0x3F80_8000); // exact RNE tie
            src[2] = f32::from_bits(0x7F80_0001); // sneaky signaling NaN
            src[3] = -0.0;
            src[4] = f32::INFINITY;
        }
        assert_modes_agree(&format!("bf16 batch len={len}"), || {
            let mut lanes = vec![0u16; len];
            simd::f32_to_bf16_batch(&src, &mut lanes);
            let mut widened = vec![0.0f32; len];
            simd::bf16_to_f32_batch(&lanes, &mut widened);
            let mut quant = src.clone();
            simd::quantize_bf16_batch(&mut quant);
            lanes
                .iter()
                .map(|&b| b as u64)
                .chain(widened.iter().map(|v| v.to_bits() as u64))
                .chain(quant.iter().map(|v| v.to_bits() as u64))
                .collect()
        });
    }
}

#[test]
fn engine_step_bitwise_across_backends() {
    // end to end: a LowRank-LR training step through the f32 engine —
    // Adam on B, Θ += ΔB·Vᵀ through gemm_nt — same bytes either mode
    use lowrank_sge::bench_util::engine_fixture;
    use lowrank_sge::coordinator::SubspaceSet;
    use lowrank_sge::estimator::engine::{GradEstimator, GradSignal, MethodShape};
    use lowrank_sge::optim::AdamConfig;
    use lowrank_sge::projection::ProjectorKind;
    use lowrank_sge::rng::Rng;

    const DIMS: [(usize, usize, usize); 2] = [(37, 29, 4), (23, 31, 3)];
    assert_modes_agree("engine lowrank-lr steps", || {
        let (mut store, slots) = engine_fixture(&DIMS, 16);
        let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
        let mut engine = GradEstimator::new(
            MethodShape::LowRankLr,
            1e-2,
            Some(sub),
            Vec::new(),
            Vec::new(),
            Some((DIMS.len(), 16, AdamConfig::default())),
        );
        let mut rng = Rng::new(97);
        engine.subspace.as_mut().unwrap().resample(&mut rng);
        for step in 0..5 {
            engine.draw_perturbations(&mut rng);
            let fp = 0.9 + step as f32 * 0.01;
            let fm = 0.8 - step as f32 * 0.02;
            engine
                .step(&mut store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, 1e-3)
                .unwrap();
        }
        let mut bits = Vec::new();
        for i in 0..store.len() {
            bits.extend(store.f32(i).unwrap().iter().map(|v| v.to_bits() as u64));
        }
        bits
    });
}
