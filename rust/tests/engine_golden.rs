//! Golden bitwise tests for the estimator-engine refactor: the engine's
//! workspace-reusing, pool-fanned pipeline must reproduce the
//! pre-refactor per-step arithmetic **bit for bit** — same ParamStore
//! bytes for the trainer shapes, same toy-MSE curves — at every thread
//! count. Each test pits the engine against an inline reference that is
//! a verbatim copy of the pre-engine implementation (fresh allocations,
//! transpose-based lifts, serial loops).

use std::sync::{Arc, Mutex};

use lowrank_sge::bench_util::engine_fixture;
use lowrank_sge::coordinator::{FullSlot, MatrixSlot, SubspaceSet};
use lowrank_sge::estimator::engine::{
    project_lift, GradEstimator, GradSignal, MethodShape, ZoTarget,
};
use lowrank_sge::estimator::mse::{mse_curve, EstimatorSpec, MseCurveConfig};
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::estimator::Family;
use lowrank_sge::linalg::{matmul, matmul_nt, Mat};
use lowrank_sge::model::ParamStore;
use lowrank_sge::optim::{Adam, AdamConfig};
use lowrank_sge::projection::{build_sampler, ProjectionSampler, ProjectorKind};
use lowrank_sge::rng::Rng;

/// Serializes tests that resize the process-global kernel pool.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// (G·V)·Vᵀ with the Vᵀ contraction stated directly in the canonical
/// fixed-lane accumulation order (`kernel::lane_dot`) — the reference
/// form of the lift. The pre-SIMD references used an explicit
/// `transpose(&v)` + GEMM here; the fixed-lane order is now the
/// canonical bits for every dot-like reduction (see the `kernel::ops`
/// module docs), so the golden reference states it through the same
/// helper rather than the blocked kernels under test.
fn lift_reference(gv: &Mat, v: &Mat) -> Mat {
    assert_eq!(gv.cols, v.cols);
    let mut out = Mat::zeros(gv.rows, v.rows);
    for i in 0..gv.rows {
        for j in 0..v.rows {
            out.data[i * v.rows + j] += lowrank_sge::kernel::lane_dot(
                &gv.data[i * gv.cols..(i + 1) * gv.cols],
                &v.data[j * v.cols..(j + 1) * v.cols],
            );
        }
    }
    out
}

// ---------------------------------------------------------------------------
// shared fixtures: a 3-matrix + head parameter store
// ---------------------------------------------------------------------------

const DIMS: [(usize, usize, usize); 3] = [(12, 8, 3), (8, 8, 2), (10, 6, 4)];
const HEAD_LEN: usize = 10;
const SIGMA: f32 = 1e-2;
const LR: f32 = 2e-3;

fn build_store() -> ParamStore {
    engine_fixture(&DIMS, HEAD_LEN).0
}

fn build_slots() -> Vec<MatrixSlot> {
    engine_fixture(&DIMS, HEAD_LEN).1
}

fn store_bits(store: &ParamStore) -> Vec<u32> {
    (0..store.len())
        .flat_map(|i| store.f32(i).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        .collect()
}

fn losses(step: u64) -> (f32, f32) {
    let fp = 0.73 + (step as f32) * 0.011;
    let fm = 0.69 - (step as f32) * 0.007;
    (fp, fm)
}

// ---------------------------------------------------------------------------
// LowRank-LR: engine vs the pre-refactor finetune inner loop
// ---------------------------------------------------------------------------

/// Pre-refactor reference: fresh `Vec` per draw, `clone`-based delta,
/// serial slot loop — copied from the old `FinetuneTrainer::run`.
fn reference_lowrank_lr(steps: u64, seed: u64) -> Vec<u32> {
    let mut store = build_store();
    let mut sub = SubspaceSet::from_slots(build_slots(), ProjectorKind::Stiefel, 1.0);
    let mut head_adam = Adam::new(HEAD_LEN, AdamConfig::default());
    let mut rng = Rng::new(seed);
    sub.resample(&mut rng);
    for step in 0..steps {
        let z_head: Vec<f32> = (0..HEAD_LEN).map(|_| rng.normal() as f32).collect();
        let zs: Vec<Vec<f32>> = sub
            .slots
            .iter()
            .map(|s| (0..s.m * s.r).map(|_| rng.normal() as f32).collect())
            .collect();
        let (fp, fm) = losses(step);
        let scale = (fp - fm) / (2.0 * SIGMA);
        for (slot, z) in sub.slots.iter_mut().zip(&zs) {
            let g: Vec<f32> = z.iter().map(|x| scale * x).collect();
            let old_b: Vec<f32> = slot.b.as_slice().to_vec();
            slot.adam.step(Arc::make_mut(&mut slot.b), &g, LR);
            let delta: Vec<f32> = slot.b.iter().zip(&old_b).map(|(n, o)| n - o).collect();
            let theta = store.f32_mut(slot.param_pos).unwrap();
            lowrank_sge::kernel::serial::gemm_nt(
                1.0f32,
                &delta,
                slot.v.as_slice(),
                theta,
                slot.m,
                slot.n,
                slot.r,
            );
        }
        let gh: Vec<f32> = z_head.iter().map(|x| scale * x).collect();
        head_adam.step(store.f32_mut(3).unwrap(), &gh, LR);
    }
    store_bits(&store)
}

fn engine_lowrank_lr(steps: u64, seed: u64) -> Vec<u32> {
    let mut store = build_store();
    let sub = SubspaceSet::from_slots(build_slots(), ProjectorKind::Stiefel, 1.0);
    let mut engine = GradEstimator::new(
        MethodShape::LowRankLr,
        SIGMA,
        Some(sub),
        Vec::new(),
        Vec::new(),
        Some((3, HEAD_LEN, AdamConfig::default())),
    );
    let mut rng = Rng::new(seed);
    engine.subspace.as_mut().unwrap().resample(&mut rng);
    for step in 0..steps {
        engine.draw_perturbations(&mut rng);
        let (fp, fm) = losses(step);
        engine
            .step(&mut store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, LR)
            .unwrap();
    }
    store_bits(&store)
}

#[test]
fn lowrank_lr_engine_matches_prerefactor_reference_bitwise() {
    let _guard = lock_pool();
    let prev = lowrank_sge::kernel::global_threads();
    let want = {
        lowrank_sge::kernel::set_global_threads(1);
        reference_lowrank_lr(7, 99)
    };
    for threads in [1usize, 4] {
        lowrank_sge::kernel::set_global_threads(threads);
        let got = engine_lowrank_lr(7, 99);
        assert_eq!(got, want, "LowRank-LR diverged at {threads} threads");
    }
    lowrank_sge::kernel::set_global_threads(prev);
}

// ---------------------------------------------------------------------------
// Vanilla-LR (FullLr): engine vs the pre-refactor MeZO-style SGD loop
// ---------------------------------------------------------------------------

fn reference_full_lr(steps: u64, seed: u64) -> Vec<u32> {
    let mut store = build_store();
    let mut rng = Rng::new(seed);
    let pool = lowrank_sge::kernel::global();
    for step in 0..steps {
        let z_head: Vec<f32> = (0..HEAD_LEN).map(|_| rng.normal() as f32).collect();
        let zs: Vec<Vec<f32>> = DIMS
            .iter()
            .map(|&(m, n, _)| (0..m * n).map(|_| rng.normal() as f32).collect())
            .collect();
        let (fp, fm) = losses(step);
        let scale = (fp - fm) / (2.0 * SIGMA);
        let alpha = -(LR * scale);
        for (i, z) in zs.iter().enumerate() {
            let theta = store.f32_mut(i).unwrap();
            lowrank_sge::kernel::axpy(&pool, alpha, z, theta);
        }
        let head = store.f32_mut(3).unwrap();
        lowrank_sge::kernel::axpy(&pool, alpha, &z_head, head);
    }
    store_bits(&store)
}

fn engine_full_lr(steps: u64, seed: u64) -> Vec<u32> {
    let mut store = build_store();
    let targets: Vec<ZoTarget> = DIMS
        .iter()
        .enumerate()
        .map(|(i, &(m, n, _))| ZoTarget { param_pos: i, m, n })
        .collect();
    let mut engine = GradEstimator::new(
        MethodShape::FullLr,
        SIGMA,
        None,
        targets,
        Vec::new(),
        Some((3, HEAD_LEN, AdamConfig::default())),
    );
    let mut rng = Rng::new(seed);
    for step in 0..steps {
        engine.draw_perturbations(&mut rng);
        let (fp, fm) = losses(step);
        engine
            .step(&mut store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, LR)
            .unwrap();
    }
    store_bits(&store)
}

#[test]
fn full_lr_engine_matches_prerefactor_reference_bitwise() {
    let _guard = lock_pool();
    let prev = lowrank_sge::kernel::global_threads();
    lowrank_sge::kernel::set_global_threads(1);
    let want = reference_full_lr(6, 17);
    for threads in [1usize, 4] {
        lowrank_sge::kernel::set_global_threads(threads);
        let got = engine_full_lr(6, 17);
        assert_eq!(got, want, "Vanilla-LR diverged at {threads} threads");
    }
    lowrank_sge::kernel::set_global_threads(prev);
}

// ---------------------------------------------------------------------------
// LowRank-IPA (pretrain shape): engine vs the pre-refactor serial loops
// ---------------------------------------------------------------------------

fn ipa_grads(step: u64) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let db: Vec<Vec<f32>> = DIMS
        .iter()
        .enumerate()
        .map(|(i, &(m, _, r))| {
            (0..m * r)
                .map(|k| (((step * 31 + i as u64 * 7 + k as u64) as f32) * 0.01).sin())
                .collect()
        })
        .collect();
    let df: Vec<Vec<f32>> = vec![(0..HEAD_LEN)
        .map(|k| (((step * 13 + k as u64) as f32) * 0.02).cos())
        .collect()];
    (db, df)
}

fn reference_lowrank_ipa(steps: u64, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut store = build_store();
    let mut sub = SubspaceSet::from_slots(build_slots(), ProjectorKind::Stiefel, 1.0);
    let mut full_adam = Adam::new(HEAD_LEN, AdamConfig::default());
    let mut rng = Rng::new(seed);
    sub.resample(&mut rng);
    for step in 0..steps {
        let (db, df) = ipa_grads(step);
        // pre-engine serial order: every subspace B first, then the
        // full-rank channels
        for (slot, g) in sub.slots.iter_mut().zip(&db) {
            slot.adam.step(Arc::make_mut(&mut slot.b), g, LR);
        }
        full_adam.step(store.f32_mut(3).unwrap(), &df[0], LR);
    }
    sub.lift(&mut store).unwrap();
    let b_bits = sub
        .slots
        .iter()
        .flat_map(|s| s.b.iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        .collect();
    (store_bits(&store), b_bits)
}

fn engine_lowrank_ipa(steps: u64, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut store = build_store();
    let sub = SubspaceSet::from_slots(build_slots(), ProjectorKind::Stiefel, 1.0);
    let full = vec![FullSlot {
        name: "head".into(),
        param_pos: 3,
        dout: usize::MAX,
        adam: Adam::new(HEAD_LEN, AdamConfig::default()),
    }];
    let mut engine =
        GradEstimator::new(MethodShape::LowRankIpa, 0.0, Some(sub), Vec::new(), full, None);
    let mut rng = Rng::new(seed);
    engine.subspace.as_mut().unwrap().resample(&mut rng);
    for step in 0..steps {
        let (db, df) = ipa_grads(step);
        let views: Vec<&[f32]> = db
            .iter()
            .map(|g| g.as_slice())
            .chain(df.iter().map(|g| g.as_slice()))
            .collect();
        let stats = engine
            .step(
                &mut store,
                GradSignal::Grads {
                    loss: 1.25,
                    slots: &views,
                    head: None,
                    grad_norm: None,
                },
                LR,
            )
            .unwrap();
        assert_eq!(stats.loss, 1.25);
    }
    let sub = engine.subspace.as_mut().unwrap();
    sub.lift(&mut store).unwrap();
    let b_bits = sub
        .slots
        .iter()
        .flat_map(|s| s.b.iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        .collect();
    (store_bits(&store), b_bits)
}

#[test]
fn lowrank_ipa_engine_matches_prerefactor_reference_bitwise() {
    let _guard = lock_pool();
    let prev = lowrank_sge::kernel::global_threads();
    lowrank_sge::kernel::set_global_threads(1);
    let (want_store, want_b) = reference_lowrank_ipa(5, 7);
    for threads in [1usize, 4] {
        lowrank_sge::kernel::set_global_threads(threads);
        let (got_store, got_b) = engine_lowrank_ipa(5, 7);
        assert_eq!(got_store, want_store, "LowRank-IPA Θ diverged at {threads} threads");
        assert_eq!(got_b, want_b, "LowRank-IPA B diverged at {threads} threads");
    }
    lowrank_sge::kernel::set_global_threads(prev);
}

// ---------------------------------------------------------------------------
// Toy MSE: engine-driven curves vs the pre-refactor serial harness
// ---------------------------------------------------------------------------

/// Verbatim pre-engine `mse_curve`: one shared sampler, rep streams
/// forked lazily, fresh allocations per estimate, transpose-based lift.
fn reference_mse_points(
    problem: &ToyProblem,
    w: &Mat,
    cfg: &MseCurveConfig,
) -> Vec<(usize, f64)> {
    let g = problem.true_gradient(w);
    let n_max = *cfg.sample_sizes.iter().max().unwrap();
    let mut rng = Rng::new(cfg.seed);
    let mut sampler: Option<Box<dyn ProjectionSampler + Send + Sync>> = match cfg.spec {
        EstimatorSpec::LowRank(kind) => {
            Some(build_sampler(kind, problem.n, cfg.r, cfg.c, None))
        }
        EstimatorSpec::FullRank => None,
    };
    let mut sums = vec![0.0f64; cfg.sample_sizes.len()];
    for rep in 0..cfg.reps {
        let mut rep_rng = rng.fork(rep as u64);
        let mut mean = Mat::zeros(problem.m, problem.n);
        let mut next_ckpt = 0usize;
        for t in 1..=n_max {
            let a = problem.sample_a(&mut rep_rng);
            let est = match (&mut sampler, cfg.family) {
                (None, Family::Ipa) => problem.ipa_estimate(w, &a),
                (None, Family::Lr) => {
                    let z = Mat::from_fn(problem.m, problem.n, |_, _| rep_rng.normal());
                    let mut wp = w.clone();
                    wp.axpy_inplace(cfg.zo_sigma, &z);
                    let mut wm = w.clone();
                    wm.axpy_inplace(-cfg.zo_sigma, &z);
                    let scale =
                        (problem.loss(&wp, &a) - problem.loss(&wm, &a)) / (2.0 * cfg.zo_sigma);
                    z.scaled(scale)
                }
                (Some(s), Family::Ipa) => {
                    let v = s.sample(&mut rep_rng);
                    let ghat = problem.ipa_estimate(w, &a);
                    // project then lift, the Vᵀ contraction in the
                    // canonical fixed-lane order
                    let gv = matmul(&ghat, &v);
                    lift_reference(&gv, &v)
                }
                (Some(s), Family::Lr) => {
                    let v = s.sample(&mut rep_rng);
                    let z = Mat::from_fn(problem.m, v.cols, |_, _| rep_rng.normal());
                    let zvt = matmul_nt(&z, &v);
                    let mut wp = w.clone();
                    wp.axpy_inplace(cfg.zo_sigma, &zvt);
                    let mut wm = w.clone();
                    wm.axpy_inplace(-cfg.zo_sigma, &zvt);
                    let scale =
                        (problem.loss(&wp, &a) - problem.loss(&wm, &a)) / (2.0 * cfg.zo_sigma);
                    zvt.scaled(scale)
                }
            };
            let inv_t = 1.0 / t as f64;
            for (m_v, e_v) in mean.data.iter_mut().zip(&est.data) {
                *m_v += (e_v - *m_v) * inv_t;
            }
            while next_ckpt < cfg.sample_sizes.len() && cfg.sample_sizes[next_ckpt] == t {
                sums[next_ckpt] += mean.sub(&g).fro_norm_sq();
                next_ckpt += 1;
            }
        }
    }
    cfg.sample_sizes
        .iter()
        .zip(&sums)
        .map(|(&n, &s)| (n, s / cfg.reps as f64))
        .collect()
}

#[test]
fn toy_mse_curves_match_prerefactor_reference_bitwise() {
    let _guard = lock_pool();
    let prev = lowrank_sge::kernel::global_threads();
    let problem = ToyProblem::small(51);
    let w = problem.eval_point(52);
    let configs = [
        (Family::Ipa, EstimatorSpec::FullRank),
        (Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel)),
        (Family::Lr, EstimatorSpec::FullRank),
        (Family::Lr, EstimatorSpec::LowRank(ProjectorKind::Gaussian)),
        (Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Coordinate)),
    ];
    for (family, spec) in configs {
        let cfg = MseCurveConfig {
            family,
            spec,
            c: 1.0,
            r: 3,
            sample_sizes: vec![2, 6],
            reps: 4,
            seed: 1234,
            zo_sigma: 1e-2,
            warmup: 10,
        };
        lowrank_sge::kernel::set_global_threads(1);
        let want = reference_mse_points(&problem, &w, &cfg);
        for threads in [1usize, 4] {
            lowrank_sge::kernel::set_global_threads(threads);
            let curve = mse_curve(&problem, &w, &cfg);
            assert_eq!(curve.points.len(), want.len());
            for ((n_got, m_got), (n_want, m_want)) in curve.points.iter().zip(&want) {
                assert_eq!(n_got, n_want);
                assert_eq!(
                    m_got.to_bits(),
                    m_want.to_bits(),
                    "{}-{} MSE diverged at {threads} threads: {m_got} vs {m_want}",
                    spec.label(),
                    family.name()
                );
            }
        }
    }
    lowrank_sge::kernel::set_global_threads(prev);
}

#[test]
fn toy_mse_csv_is_thread_count_invariant() {
    let _guard = lock_pool();
    let prev = lowrank_sge::kernel::global_threads();
    let mut opts = lowrank_sge::exp::toy_mse::ToyMseOptions::quick(Family::Ipa, false);
    opts.reps = 2;
    opts.sample_sizes = vec![3, 7];
    opts.c_grid = vec![1.0];
    let dir = std::env::temp_dir()
        .join(format!("lowrank_sge_engine_golden_p{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut bytes = Vec::new();
    for threads in [1usize, 4] {
        lowrank_sge::kernel::set_global_threads(threads);
        let csv = dir.join(format!("fig_t{threads}.csv"));
        lowrank_sge::exp::toy_mse::run(&opts, &csv).unwrap();
        bytes.push(std::fs::read(&csv).unwrap());
    }
    lowrank_sge::kernel::set_global_threads(prev);
    let _ = std::fs::remove_dir_all(&dir);
    assert!(!bytes[0].is_empty());
    assert_eq!(bytes[0], bytes[1], "toy-MSE CSV bytes diverged across thread counts");
}

#[test]
fn new_project_lift_matches_transpose_form_bitwise() {
    // the engine's gemm_nt lift vs the reference form stated through
    // the canonical fixed-lane helper: both accumulate each element in
    // the fixed-lane order, so the bits are identical.
    let _guard = lock_pool();
    let mut rng = Rng::new(5);
    for (m, n, r) in [(7, 9, 3), (40, 33, 8), (64, 64, 4)] {
        let g = Mat::from_fn(m, n, |_, _| rng.normal());
        let mut s = build_sampler(ProjectorKind::Stiefel, n, r, 1.0, None);
        let v = s.sample(&mut rng);
        let fast = project_lift(&g, &v);
        let gv = matmul(&g, &v);
        let slow = lift_reference(&gv, &v);
        for (x, y) in fast.data.iter().zip(&slow.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "project_lift bits diverged at {m}x{n}x{r}");
        }
    }
}
