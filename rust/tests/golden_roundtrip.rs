//! Integration: the Rust runtime executes every golden-carrying artifact
//! and reproduces the Python-recorded outputs. This is the L2↔L3
//! numerical contract test.

use lowrank_sge::runtime::Runtime;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("INDEX.txt").exists()
}

#[test]
fn golden_artifacts_reproduce_python_outputs() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    for name in ["lm_grad_s", "lm_eval_s", "lm_grad_s_pallas"] {
        let art = rt.load(name).unwrap();
        let inputs = rt.golden_inputs(&art).unwrap();
        let expected = rt.golden_outputs(&art).unwrap();
        let got = art.execute(&inputs).unwrap();
        assert_eq!(got.len(), expected.len(), "{name}: output arity");
        for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
            let scale = e
                .as_f32()
                .map(|d| d.iter().fold(0f32, |a, &b| a.max(b.abs())))
                .unwrap_or(1.0)
                .max(1e-3);
            let diff = g.max_abs_diff(e).unwrap();
            assert!(
                diff <= 1e-4 * scale + 1e-6,
                "{name}: output {i} diff {diff} (scale {scale})"
            );
        }
        println!("{name}: {} outputs match golden", got.len());
    }
}

#[test]
fn pallas_artifact_matches_jnp_artifact_on_same_inputs() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let jnp = rt.load("lm_grad_s").unwrap();
    let pls = rt.load("lm_grad_s_pallas").unwrap();
    let inputs = rt.golden_inputs(&jnp).unwrap();
    let out_j = jnp.execute(&inputs).unwrap();
    let out_p = pls.execute(&inputs).unwrap();
    // loss
    let (lj, lp) = (out_j[0].scalar().unwrap(), out_p[0].scalar().unwrap());
    assert!((lj - lp).abs() < 1e-4 * lj.abs().max(1.0), "loss: jnp {lj} vs pallas {lp}");
    // all gradients
    for i in 1..out_j.len() {
        let diff = out_j[i].max_abs_diff(&out_p[i]).unwrap();
        let scale = out_j[i]
            .as_f32()
            .unwrap()
            .iter()
            .fold(0f32, |a, &b| a.max(b.abs()))
            .max(1e-3);
        assert!(diff < 5e-3 * scale + 1e-5, "output {i}: kernel/oracle diff {diff}");
    }
}

#[test]
fn param_store_checkpoints_init_params_bit_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use lowrank_sge::ckpt::{load_checkpoint, save_checkpoint, Checkpointable, ResumeSpec};
    use lowrank_sge::model::ParamStore;

    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let art = rt.load("lm_grad_s").unwrap();
    let store = ParamStore::load_init(&dir, "s", &art.manifest).unwrap();

    let ckpt_dir = std::env::temp_dir().join("lowrank_sge_golden_param_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let groups = [("params", store.state_dict())];
    save_checkpoint(&ckpt_dir, 1, &[], &groups, 0).unwrap();

    let mut restored = ParamStore::load_init(&dir, "s", &art.manifest).unwrap();
    // scramble, then restore from disk
    for i in 0..restored.len() {
        if let Ok(d) = restored.f32_mut(i) {
            d.iter_mut().for_each(|v| *v = -1.0);
        }
    }
    let loaded = load_checkpoint(&ckpt_dir, ResumeSpec::Latest).unwrap();
    restored.load_state(loaded.group("params").unwrap()).unwrap();
    for i in 0..store.len() {
        let (a, b) = (store.f32(i), restored.f32(i));
        if let (Ok(a), Ok(b)) = (a, b) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "param {i} not bit-exact");
            }
        }
    }
}

#[test]
fn runtime_rejects_wrong_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let art = rt.load("lm_eval_s").unwrap();
    let mut inputs = rt.golden_inputs(&art).unwrap();
    // corrupt one shape
    if let lowrank_sge::runtime::HostTensor::F32 { shape, .. } = &mut inputs[0] {
        shape.swap(0, 1);
    }
    assert!(art.execute(&inputs).is_err());
    // wrong arity
    let art2 = rt.load("lm_eval_s").unwrap();
    let short = rt.golden_inputs(&art2).unwrap()[1..].to_vec();
    assert!(art2.execute(&short).is_err());
}
