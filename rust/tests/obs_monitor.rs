//! Integration pins for the run-health monitor and the
//! estimator-quality probes (`obs::monitor` + `obs::quality`):
//!
//! * the stall watchdog never flags a slow-but-alive rank, and does
//!   flag a real stall;
//! * an injected panic produces a parseable postmortem blackbox that
//!   carries the span ring;
//! * the TCP status endpoint serves a valid JSON snapshot line;
//! * quality probing leaves the trained bytes bitwise identical at
//!   thread counts 1 and 4 (the probes draw from a dedicated forked
//!   RNG stream — the non-perturbation contract of `crate::obs`
//!   extended to the paired probe steps).
//!
//! Every test takes one shared lock: the monitor state (enabled flag,
//! watermark slab, stall counter, watchdog thread, panic hook) is
//! process-global, and `monitor::configure` is first-call-wins — so
//! all tests point it at the same blackbox dir.

use std::io::BufRead;
use std::sync::Mutex;
use std::time::Duration;

use lowrank_sge::bench_util::engine_fixture;
use lowrank_sge::coordinator::SubspaceSet;
use lowrank_sge::estimator::engine::{GradEstimator, GradSignal, MethodShape};
use lowrank_sge::obs;
use lowrank_sge::obs::monitor::{self, Phase};
use lowrank_sge::obs::quality::QualityProbe;
use lowrank_sge::optim::AdamConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn blackbox_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lowrank_sge_obs_monitor");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Shared monitor setup: whichever test runs first wins the
/// `configure` call; they all pass the same rank and blackbox dir, so
/// the order doesn't matter.
fn setup() {
    monitor::configure(0, Some(&blackbox_dir()));
}

#[test]
fn watchdog_tolerates_slow_but_alive_then_flags_a_stall() {
    let _g = guard();
    setup();
    monitor::stamp(Phase::Execute, 0);
    monitor::start_watchdog(600);
    // let the watchdog observe fresh progress before taking a baseline
    // (its poll period is timeout/4 = 150 ms)
    std::thread::sleep(Duration::from_millis(200));
    let baseline = monitor::stall_count();
    // slow but alive: stamps keep arriving at 4x under the timeout
    for step in 1..=5u64 {
        monitor::stamp(Phase::Update, step);
        std::thread::sleep(Duration::from_millis(150));
    }
    assert_eq!(
        monitor::stall_count(),
        baseline,
        "watchdog flagged a rank that stamped every 150 ms (timeout 600 ms)"
    );
    // now a real stall: no watermark advances for well past the timeout
    std::thread::sleep(Duration::from_millis(1600));
    assert!(
        monitor::stall_count() > baseline,
        "watchdog missed a 1600 ms stall (timeout 600 ms)"
    );
    // progress resumes — re-arms the watchdog for any later test
    monitor::stamp(Phase::Update, 6);
}

#[test]
fn injected_panic_writes_a_parseable_blackbox() {
    let _g = guard();
    setup();
    // record a span so the flight recorder has something to carry
    obs::span::set_enabled(true);
    {
        let _p = obs::phase("test", "blackbox-probe-span", "");
    }
    monitor::stamp(Phase::Ckpt, 7);
    let path = blackbox_dir().join("postmortem.rank0.json");
    let _ = std::fs::remove_file(&path);
    let h = std::thread::spawn(|| panic!("injected: obs_monitor blackbox test"));
    assert!(h.join().is_err(), "the injected panic must unwind its thread");
    obs::span::set_enabled(false);
    let text = std::fs::read_to_string(&path)
        .expect("the panic hook must have written the postmortem blackbox");
    let line = text.trim();
    assert!(monitor::check_json_line(line), "blackbox is not valid JSON: {line}");
    assert!(line.contains("blackbox-probe-span"), "span ring missing from blackbox: {line}");
    assert!(line.contains("injected: obs_monitor blackbox test"), "{line}");
    assert!(line.contains("\"watermarks\":["), "{line}");
    assert!(line.contains("\"metrics\":{"), "{line}");
}

#[test]
fn status_endpoint_serves_one_valid_snapshot_line() {
    let _g = guard();
    setup();
    monitor::stamp(Phase::Eval, 12);
    // port 0: the OS picks — serve_status returns the bound address
    let bound = monitor::serve_status("127.0.0.1:0").expect("binding the status endpoint");
    let stream = std::net::TcpStream::connect(bound).expect("connecting to the endpoint");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut line = String::new();
    std::io::BufReader::new(stream).read_line(&mut line).expect("reading one snapshot");
    let line = line.trim();
    assert!(monitor::check_json_line(line), "endpoint snapshot is not valid JSON: {line}");
    assert!(line.contains("\"registry\":{"), "{line}");
    assert!(line.contains("\"watermarks\":["), "{line}");
    assert!(line.contains("\"eval\""), "the stamped phase must appear: {line}");
}

// ------------------------------------------------ probing non-perturbation

const DIMS: [(usize, usize, usize); 3] = [(48, 32, 4), (32, 32, 2), (40, 24, 8)];
const HEAD_LEN: usize = 24;
const STEPS: u64 = 23;

/// The `tests/obs_determinism.rs` engine fixture with the trainers'
/// rotating quality probe spliced in at the same point in the step
/// loop (a deterministic synthetic dB stands in for the reduced
/// gradient — `probe_quality` is read-only either way, so only the
/// probe RNG could possibly leak into training).
fn run_fixture(threads: usize, probe_every: u64) -> Vec<u8> {
    lowrank_sge::kernel::set_global_threads(threads);
    let (mut store, slots) = engine_fixture(&DIMS, HEAD_LEN);
    let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    let mut engine = GradEstimator::new(
        MethodShape::LowRankLr,
        1e-2,
        Some(sub),
        Vec::new(),
        Vec::new(),
        Some((DIMS.len(), HEAD_LEN, AdamConfig::default())),
    );
    let names: Vec<String> = (0..DIMS.len()).map(|i| format!("m{i}")).collect();
    let mut quality = QualityProbe::new(7, probe_every, names);
    let mut rng = Rng::new(7);
    engine.subspace.as_mut().unwrap().resample(&mut rng);
    for step in 0..STEPS {
        if step == 11 {
            engine.subspace.as_mut().unwrap().resample(&mut rng);
        }
        engine.draw_perturbations(&mut rng);
        let fp = 0.8 + (step as f32) * 0.003;
        let fm = 0.7 - (step as f32) * 0.002;
        engine
            .step(&mut store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, 1e-3)
            .unwrap();
        if let Some(i) = quality.rotating_slot(step) {
            let (m, _n, r) = DIMS[i];
            let len = m * r;
            let db: Vec<f32> =
                (0..len).map(|j| ((j as f32) * 0.37 + (step as f32) * 0.11).sin()).collect();
            let u = quality.draw_direction(len).to_vec();
            if let Some(p) = engine.probe_quality(i, &db, &u) {
                quality.observe(i, step, p);
            }
        }
    }
    let mut out = Vec::new();
    for i in 0..store.len() {
        for v in store.f32(i).unwrap() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

#[test]
fn trained_bytes_identical_with_probing_enabled() {
    let _g = guard();
    for threads in [1usize, 4] {
        obs::span::set_enabled(false);
        obs::metrics::set_enabled(false);
        let plain = run_fixture(threads, 0);

        obs::span::set_enabled(true);
        obs::metrics::set_enabled(true);
        let probed = run_fixture(threads, 4);
        obs::span::set_enabled(false);
        obs::metrics::set_enabled(false);

        // assert! (not assert_eq!) so a failure doesn't dump every byte
        assert!(
            plain == probed,
            "quality probing perturbed the trained bytes at {threads} thread(s)"
        );
        assert!(!plain.is_empty() && plain.iter().any(|&b| b != 0));
    }
}
