//! End-to-end `launch` runner coverage, driving the real binary:
//!
//! * a 2-rank `comm-check` smoke (no artifacts needed): both ranks
//!   rendezvous, run ring + tree all-reduces, and report the identical
//!   result CRC — once in the suite dtype and once forced to bf16 via
//!   `--comm-dtype` (the compressed lane's ring ≡ tree check);
//! * failure propagation: a failing child makes `launch` exit
//!   non-zero, and — the fast-failure regression — a rank that dies
//!   *before rendezvous* terminates the surviving ranks immediately
//!   instead of letting them poll dead address files until the comm
//!   timeout;
//! * (artifact-gated) the acceptance criterion: `launch --nproc 2
//!   pretrain --workers 2` writes a rank-0 checkpoint bitwise identical
//!   to the single-process 2-shard in-process DDP run at the same
//!   seeds (an f32-lane contract, so the dtype is pinned there).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_lowrank-sge");

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("INDEX.txt").exists()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lowrank_launch_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn launch_two_rank_comm_check_agrees_bitwise() {
    let out = Command::new(BIN)
        .args(["launch", "--nproc", "2", "comm-check", "--len", "4099"])
        .output()
        .expect("running the launch binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "launch failed\nstdout:\n{stdout}\nstderr:\n{stderr}");
    let crcs: Vec<&str> = stdout
        .lines()
        .filter(|l| l.contains("comm-check ok"))
        .filter_map(|l| l.split("crc=").nth(1))
        .map(|t| t.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(crcs.len(), 2, "expected both ranks to report ok\nstdout:\n{stdout}");
    assert_eq!(crcs[0], crcs[1], "ranks reduced to different bits\nstdout:\n{stdout}");
    assert!(stdout.contains("[rank 0]") && stdout.contains("[rank 1]"), "{stdout}");
}

#[test]
fn launch_single_rank_comm_check_works() {
    let out = Command::new(BIN)
        .args(["launch", "--nproc", "1", "comm-check", "--len", "101"])
        .output()
        .expect("running the launch binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stdout:\n{stdout}");
    assert!(stdout.contains("comm-check ok rank=0 world=1"), "{stdout}");
}

#[test]
fn launch_two_rank_comm_check_agrees_bitwise_in_bf16() {
    // `--comm-dtype bf16` rides the runner → env → from_env lane;
    // comm-check's internal ring-vs-tree comparison then pins the
    // compressed determinism contract inside a real launch world
    let out = Command::new(BIN)
        .args(["launch", "--nproc", "2", "--comm-dtype", "bf16", "comm-check", "--len", "9001"])
        .output()
        .expect("running the launch binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "launch failed\nstdout:\n{stdout}\nstderr:\n{stderr}");
    let crcs: Vec<&str> = stdout
        .lines()
        .filter(|l| l.contains("comm-check ok") && l.contains("dtype=bf16"))
        .filter_map(|l| l.split("crc=").nth(1))
        .map(|t| t.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(crcs.len(), 2, "expected both ranks to report ok in bf16\nstdout:\n{stdout}");
    assert_eq!(crcs[0], crcs[1], "bf16 ranks reduced to different bits\nstdout:\n{stdout}");
}

#[test]
fn launch_rejects_a_bad_comm_dtype() {
    let out = Command::new(BIN)
        .args(["launch", "--nproc", "1", "--comm-dtype", "fp8", "comm-check"])
        .output()
        .expect("running the launch binary");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("dtype"), "{stderr}");
}

#[test]
fn launch_propagates_a_failing_child() {
    let out = Command::new(BIN)
        .args(["launch", "--nproc", "2", "definitely-not-a-subcommand"])
        .output()
        .expect("running the launch binary");
    assert!(!out.status.success(), "a failing child must fail the launch");
}

/// The fast-failure regression: rank 1 exits 1 *before rendezvous*
/// (`comm-check --fail-rank 1`), while rank 0 sits in its address poll
/// with a deliberately long comm timeout. The old runner waited on
/// children strictly in rank order, so it blocked on rank 0 for the
/// full timeout before even observing rank 1's exit; the fixed runner
/// observes the failure in its poll sweep, kills rank 0, and returns
/// rank 1's status immediately.
#[test]
fn launch_terminates_survivors_when_a_rank_dies_before_rendezvous() {
    let t0 = Instant::now();
    let out = Command::new(BIN)
        .args([
            "launch",
            "--nproc",
            "2",
            "--comm-timeout-ms",
            "120000",
            "comm-check",
            "--fail-rank",
            "1",
            "--len",
            "64",
        ])
        .output()
        .expect("running the launch binary");
    let elapsed = t0.elapsed();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "the dead rank's exit code must propagate\n{stderr}");
    assert!(
        elapsed < Duration::from_secs(30),
        "runner took {elapsed:?} — it waited out the comm timeout instead of \
         terminating the survivors\nstderr:\n{stderr}"
    );
    assert!(
        stderr.contains("terminating") && stderr.contains("rank 1"),
        "runner did not report the fast-failure path: {stderr}"
    );
}

#[test]
fn launch_rejects_unknown_runner_flags() {
    let out = Command::new(BIN)
        .args(["launch", "--nporc", "2", "comm-check"])
        .output()
        .expect("running the launch binary");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown runner flag"), "{stderr}");
}

/// The acceptance criterion: a 2-rank launch writes the bitwise-same
/// rank-0 checkpoint as the single-process 2-worker in-process run.
#[test]
fn launch_pretrain_checkpoint_matches_single_process_bitwise() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let single_dir = fresh_dir("pretrain_single");
    let launch_dir = fresh_dir("pretrain_launch");
    let common = [
        "--scale",
        "s",
        "--steps",
        "4",
        "--k",
        "2",
        "--workers",
        "2",
        "--seed",
        "33",
        "--eval-every",
        "0",
        "--save-every",
        "4",
        "--keep-last",
        "0",
    ];
    let run = |prefix: &[&str], ckpt_dir: &Path| {
        let mut args: Vec<String> = prefix.iter().map(|s| s.to_string()).collect();
        args.push("pretrain".to_string());
        args.extend(common.iter().map(|s| s.to_string()));
        args.push("--ckpt-dir".to_string());
        args.push(ckpt_dir.display().to_string());
        let out = Command::new(BIN)
            .args(&args)
            .env("LOWRANK_SGE_ARTIFACTS", artifacts_dir())
            // single-process ≡ multi-process bitwise is the f32 lane's
            // contract; pin it so the bf16 CI matrix can't skew this test
            .env("LOWRANK_COMM_DTYPE", "f32")
            .output()
            .expect("running pretrain");
        assert!(
            out.status.success(),
            "pretrain run failed ({args:?})\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    };
    run(&[], &single_dir);
    run(&["launch", "--nproc", "2"], &launch_dir);

    let single_step = lowrank_sge::ckpt::Layout::new(&single_dir).step_dir(4);
    let launch_step = lowrank_sge::ckpt::Layout::new(&launch_dir).step_dir(4);
    for file in ["MANIFEST", "params.tsr", "subspace.tsr", "full.tsr", "rng.tsr"] {
        let a = std::fs::read(single_step.join(file))
            .unwrap_or_else(|e| panic!("single-process {file}: {e}"));
        let b = std::fs::read(launch_step.join(file))
            .unwrap_or_else(|e| panic!("launch {file}: {e}"));
        assert_eq!(a, b, "checkpoint file {file} differs between topologies");
    }
}
