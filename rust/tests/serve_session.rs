//! Serve-daemon integration: the multi-tenant seam must not bend the
//! determinism contract. A single-job serve run checkpoints bitwise
//! identically to the standalone `finetune` subcommand; concurrent
//! tenants match the same jobs run sequentially; admission control
//! rejects over the wire with a reason; and copy-on-write base
//! checkouts keep the base payloads unduplicated until the first
//! divergent write (asserted against the tracked-allocator ledger).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use lowrank_sge::coordinator::{FinetuneTrainer, TrainSession as _};
use lowrank_sge::obs::TrackedAlloc;
use lowrank_sge::runtime::Runtime;
use lowrank_sge::serve::{client, run_serve_with, BaseModelCache, JobSpec, ServeConfig};

// The CoW-ledger test reads live heap bytes, and the daemon tests
// resize the global kernel pool: both want the binary to themselves.
#[global_allocator]
static GLOBAL: TrackedAlloc = TrackedAlloc;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("INDEX.txt").exists()
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Relative path → file bytes for every file under `root`.
fn dir_snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(base: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(base, &path, out);
            } else {
                let rel = path.strip_prefix(base).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

fn assert_dirs_bitwise_equal(a: &Path, b: &Path, what: &str) {
    let (sa, sb) = (dir_snapshot(a), dir_snapshot(b));
    assert_eq!(
        sa.keys().collect::<Vec<_>>(),
        sb.keys().collect::<Vec<_>>(),
        "{what}: file sets differ between {a:?} and {b:?}"
    );
    for (rel, bytes) in &sa {
        assert_eq!(bytes, &sb[rel], "{what}: {rel} differs between {a:?} and {b:?}");
    }
}

/// Start a daemon on an ephemeral port; returns (addr, join handle).
fn spawn_daemon(
    cfg: ServeConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<lowrank_sge::serve::ServeReport>>)
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || run_serve_with(cfg, Some(tx)));
    let bound = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("daemon never announced its address");
    (bound.to_string(), handle)
}

const TIMEOUT: Duration = Duration::from_secs(10);

fn wait_done(addr: &str, job: u64) -> Vec<(String, String)> {
    let fields = client::wait(
        addr,
        job,
        Duration::from_millis(500),
        Instant::now() + Duration::from_secs(300),
    )
    .unwrap();
    assert_eq!(
        client::field(&fields, "state"),
        Some("done"),
        "job {job} did not finish cleanly: {fields:?}"
    );
    fields
}

#[test]
fn single_job_serve_matches_standalone_finetune_bitwise() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let _g = serialize();
    let dir = artifacts_dir();
    let spec = JobSpec { steps: 12, k_interval: 4, save_every: 6, ..JobSpec::default() };

    for threads in [1usize, 4] {
        // standalone reference (run() is begin + step_once* + finish_run
        // — the very loop the daemon drives through the session seam)
        lowrank_sge::kernel::set_global_threads(threads);
        let standalone_ckpt = fresh_dir(&format!("lowrank_sge_serve_ref_t{threads}"));
        let mut rt = Runtime::new(&dir).unwrap();
        let reference = FinetuneTrainer::new(
            &mut rt,
            &dir,
            spec.to_config(Some(standalone_ckpt.clone())),
        )
        .unwrap()
        .run()
        .unwrap();
        drop(rt);

        // the same spec as the only tenant of a serve daemon
        let serve_root = fresh_dir(&format!("lowrank_sge_serve_one_t{threads}"));
        let cfg = ServeConfig {
            artifacts_dir: dir.clone(),
            ckpt_root: serve_root.clone(),
            max_active: 1,
            threads,
            ..ServeConfig::default()
        };
        let (addr, handle) = spawn_daemon(cfg);
        let job = client::submit(&addr, &spec, TIMEOUT).unwrap();
        let fields = wait_done(&addr, job);
        let fetched = client::fetch(&addr, job, TIMEOUT).unwrap();
        client::shutdown(&addr, TIMEOUT).unwrap();
        let report = handle.join().unwrap().unwrap();
        assert_eq!((report.done, report.failed), (1, 0));

        // the final eval metric agrees bitwise (f64 Display round-trips)
        let metric: f64 =
            client::field(&fetched, "metric").expect("fetch reply has a metric").parse().unwrap();
        assert_eq!(
            metric.to_bits(),
            reference.accuracy.to_bits(),
            "serve accuracy {metric} != standalone {} at {threads} threads",
            reference.accuracy
        );
        assert_eq!(client::field(&fields, "step"), Some(spec.steps.to_string().as_str()));

        // and every checkpoint byte agrees
        assert_dirs_bitwise_equal(
            &standalone_ckpt,
            &serve_root.join(format!("job-{job}")),
            &format!("{threads}-thread checkpoints"),
        );
    }
}

#[test]
fn concurrent_jobs_on_shared_base_match_sequential() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let _g = serialize();
    let dir = artifacts_dir();
    // same method ⇒ same base key ⇒ one shared CoW base in the daemon
    let spec_a = JobSpec { steps: 8, k_interval: 4, save_every: 4, seed: 11, ..JobSpec::default() };
    let spec_b = JobSpec { seed: 22, ..spec_a.clone() };

    let mut metrics: Vec<Vec<u64>> = Vec::new();
    let mut roots: Vec<PathBuf> = Vec::new();
    for (mode, max_active) in [("concurrent", 2usize), ("sequential", 1usize)] {
        let root = fresh_dir(&format!("lowrank_sge_serve_{mode}"));
        let cfg = ServeConfig {
            artifacts_dir: dir.clone(),
            ckpt_root: root.clone(),
            max_active,
            ..ServeConfig::default()
        };
        let (addr, handle) = spawn_daemon(cfg);
        // both submitted up front: at max_active 2 they interleave
        // round-robin; at 1 the second waits for the first
        let ja = client::submit(&addr, &spec_a, TIMEOUT).unwrap();
        let jb = client::submit(&addr, &spec_b, TIMEOUT).unwrap();
        assert_eq!((ja, jb), (1, 2));
        let mut bits = Vec::new();
        for job in [ja, jb] {
            wait_done(&addr, job);
            let fetched = client::fetch(&addr, job, TIMEOUT).unwrap();
            let metric: f64 = client::field(&fetched, "metric").unwrap().parse().unwrap();
            bits.push(metric.to_bits());
        }
        client::shutdown(&addr, TIMEOUT).unwrap();
        let report = handle.join().unwrap().unwrap();
        assert_eq!((report.done, report.failed), (2, 0), "{mode} run");
        metrics.push(bits);
        roots.push(root);
    }

    assert_eq!(metrics[0], metrics[1], "interleaving changed a job's final metric");
    for job in [1u64, 2] {
        assert_dirs_bitwise_equal(
            &roots[0].join(format!("job-{job}")),
            &roots[1].join(format!("job-{job}")),
            &format!("job {job} checkpoints"),
        );
    }
}

#[test]
fn admission_control_rejects_over_the_wire() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let _g = serialize();
    let dir = artifacts_dir();

    // queue-cap rejection: one open job fills the daemon
    let cfg = ServeConfig {
        artifacts_dir: dir.clone(),
        ckpt_root: fresh_dir("lowrank_sge_serve_admit"),
        max_active: 1,
        max_open: 1,
        ..ServeConfig::default()
    };
    let (addr, handle) = spawn_daemon(cfg);
    let long = JobSpec { steps: 5000, ..JobSpec::default() };
    let job = client::submit(&addr, &long, TIMEOUT).unwrap();
    // wait until the scheduler owns the job, so the later cancel
    // exercises the running-job teardown path (not the queued fast path)
    let started = Instant::now() + Duration::from_secs(60);
    loop {
        let fields = client::status(&addr, job, TIMEOUT).unwrap();
        match client::field(&fields, "state") {
            Some("running") => break,
            Some("queued") if Instant::now() < started => {
                std::thread::sleep(Duration::from_millis(50));
            }
            other => panic!("job {job} stuck in state {other:?}"),
        }
    }
    let err = client::submit(&addr, &JobSpec::default(), TIMEOUT).unwrap_err().to_string();
    assert!(err.contains("queue full"), "unexpected rejection reason: {err}");
    // cancellation frees the slot mid-run, and the daemon drains cleanly
    client::cancel(&addr, job, TIMEOUT).unwrap();
    client::shutdown(&addr, TIMEOUT).unwrap();
    let report = handle.join().unwrap().unwrap();
    assert_eq!((report.done, report.cancelled), (0, 1));

    // memory-budget rejection: a 1-byte budget is always exhausted
    let cfg = ServeConfig {
        artifacts_dir: dir,
        ckpt_root: fresh_dir("lowrank_sge_serve_membudget"),
        mem_budget_bytes: 1,
        ..ServeConfig::default()
    };
    let (addr, handle) = spawn_daemon(cfg);
    let err = client::submit(&addr, &JobSpec::default(), TIMEOUT).unwrap_err().to_string();
    assert!(err.contains("memory budget"), "unexpected rejection reason: {err}");
    client::shutdown(&addr, TIMEOUT).unwrap();
    let report = handle.join().unwrap().unwrap();
    assert_eq!(report, lowrank_sge::serve::ServeReport::default());
}

#[test]
fn cow_checkouts_share_base_payloads_until_divergence() {
    use lowrank_sge::model::ParamStore;
    use lowrank_sge::runtime::{DType, HostTensor, TensorSpec};

    let _g = serialize();
    const ELEMS: usize = 1 << 20; // 4 MB payload
    const PAYLOAD: usize = ELEMS * 4;
    let toy = || {
        let spec = TensorSpec {
            index: 0,
            name: "params[w]".to_string(),
            dtype: DType::F32,
            shape: vec![ELEMS],
        };
        let t = HostTensor::f32(vec![ELEMS], vec![1.0; ELEMS]);
        ParamStore::from_parts(vec![spec], vec![t])
    };

    let mut cache = BaseModelCache::new();
    let before_master = TrackedAlloc::live_bytes();
    let first = cache.checkout("clf_zo_lowrank", toy).unwrap();
    let after_master = TrackedAlloc::live_bytes();
    assert!(
        after_master - before_master >= PAYLOAD,
        "loading the master should cost the full payload"
    );

    // N more tenants: Arc bumps, not copies
    let mut checkouts = vec![first];
    for _ in 0..8 {
        checkouts.push(cache.checkout("clf_zo_lowrank", toy).unwrap());
    }
    let after_checkouts = TrackedAlloc::live_bytes();
    let growth = after_checkouts.saturating_sub(after_master);
    assert!(
        growth < PAYLOAD / 4,
        "9 CoW checkouts grew the heap by {growth} B — payloads were duplicated"
    );

    // first divergent write unshares exactly one tenant's copy
    checkouts[0].f32_mut(0).unwrap()[0] = 2.0;
    let after_write = TrackedAlloc::live_bytes();
    let write_growth = after_write.saturating_sub(after_checkouts);
    assert!(
        write_growth >= PAYLOAD * 3 / 4,
        "divergent write grew the heap by only {write_growth} B — no private copy was made"
    );
    assert!(
        write_growth < PAYLOAD * 2,
        "divergent write grew the heap by {write_growth} B — more than one copy"
    );
    // neighbors still read the master's bytes
    assert_eq!(checkouts[1].f32(0).unwrap()[0], 1.0);
    assert_eq!(checkouts[0].f32(0).unwrap()[0], 2.0);
}

#[test]
fn session_seam_reports_progress_and_summary() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let _g = serialize();
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let spec = JobSpec { steps: 6, k_interval: 3, ..JobSpec::default() };
    let mut session =
        lowrank_sge::coordinator::FinetuneSession::new(&mut rt, &dir, spec.to_config(None))
            .unwrap();
    assert_eq!(session.progress(), (0, 6));
    let mut steps = 0u64;
    while session.step().unwrap() == lowrank_sge::coordinator::SessionStatus::Running {
        steps += 1;
        session.poll_saves().unwrap();
        assert_eq!(session.progress().0, steps);
    }
    assert_eq!(steps, 6);
    let summary = session.finish().unwrap();
    assert_eq!((summary.kind, summary.steps_done), ("finetune", 6));
    assert!(summary.metric.unwrap().is_finite());
    assert!(session.result().is_some());
    // stepping a finished session is a loud error, not UB
    assert!(session.step().is_err());
}
