//! Fine-tuning with the LR (forward-only) family — the paper's §6.2.1
//! scenario: adapt the classifier to a task using the antithetic
//! two-point ZO estimator in a Stiefel-sampled subspace, never building
//! a backward graph.
//!
//! Run: `cargo run --release --example finetune_zo -- [task] [steps]`
//! Tasks: sst2 sst5 snli mnli rte trec

use lowrank_sge::coordinator::{FinetuneConfig, FinetuneMethod, FinetuneTrainer};
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let task = args.get(1).cloned().unwrap_or_else(|| "sst2".to_string());
    let steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::new(dir)?;

    // zero-shot baseline first
    let zs_cfg = FinetuneConfig::quick(&task, FinetuneMethod::ZeroShot);
    let zero_shot = FinetuneTrainer::new(&mut rt, dir, zs_cfg)?.run()?.accuracy;
    println!("{task}: zero-shot accuracy {:.3}", zero_shot);

    // Stiefel LowRank-LR vs the Gaussian baseline
    for kind in [ProjectorKind::Stiefel, ProjectorKind::Gaussian] {
        let cfg = FinetuneConfig {
            steps,
            ..FinetuneConfig::quick(&task, FinetuneMethod::LowRankLr(kind))
        };
        let mut trainer = FinetuneTrainer::new(&mut rt, dir, cfg)?;
        let res = trainer.run()?;
        println!(
            "{task}: {}-LowRank-LR  acc {:.3}  final loss {:.4}  step {:.4}s",
            kind.name(),
            res.accuracy,
            res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
            res.log.mean_step_time(3).unwrap_or(f64::NAN),
        );
        res.log.write_csv(std::path::Path::new(&format!(
            "results/finetune_zo_{task}_{}.csv",
            kind.name()
        )))?;
    }
    println!("loss curves written to results/finetune_zo_{task}_*.csv");
    Ok(())
}
