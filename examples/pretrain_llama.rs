//! End-to-end pretraining driver — the repository's E2E validation run
//! (recorded in EXPERIMENTS.md §E2E).
//!
//! Trains the LLaMA-proxy LM with Stiefel LowRank-IPA (Algorithm 1) on
//! the synthetic Zipf–Markov corpus through the full three-layer stack
//! (rust coordinator → PJRT → AOT-compiled JAX graph → Pallas-validated
//! kernels), with 2 simulated DDP workers, and logs the loss curve.
//!
//! Run: `cargo run --release --example pretrain_llama -- [steps] [scale]`

use lowrank_sge::coordinator::{PretrainConfig, PretrainTrainer};
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let scale = args.get(2).cloned().unwrap_or_else(|| "s".to_string());

    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::new(dir)?;
    let cfg = PretrainConfig {
        scale: scale.clone(),
        sampler: ProjectorKind::Stiefel,
        c: 1.0,
        k_interval: 25,
        steps,
        lr: 2e-3,
        warmup: (steps / 20).max(2),
        clip: 1.0,
        weight_decay: 0.05,
        seed: 2026,
        workers: 2,
        eval_every: (steps / 8).max(1),
        eval_batches: 2,
        threads: 0,
        ckpt: Default::default(),
    };
    println!(
        "pretraining llama-{scale} for {steps} steps (Stiefel LowRank-IPA, K = {}, 2 DDP workers)",
        cfg.k_interval
    );
    let mut trainer = PretrainTrainer::new(&mut rt, dir, cfg)?;
    let res = trainer.run()?;

    println!("\nstep   loss     lr        step-time");
    for r in res.log.records.iter().step_by((steps as usize / 20).max(1)) {
        println!("{:<6} {:<8.4} {:<9.2e} {:.3}s", r.step, r.loss, r.lr, r.step_time_s);
    }
    println!("\neval series (held-out loss):");
    for (s, v) in &res.log.evals {
        println!("  step {s:<6} eval loss {v:.4}");
    }
    println!(
        "\nfinal: train {:.4} (tail {:.4}), eval {:?}, mean step {:.3}s",
        res.log.final_train_loss().unwrap(),
        res.log.tail_mean_loss(10).unwrap(),
        res.final_eval_loss,
        res.log.mean_step_time(3).unwrap()
    );
    println!(
        "memory story: B subspace {} elements vs {} full parameters ({}×)",
        res.b_elements,
        res.params_elements,
        res.params_elements / res.b_elements.max(1)
    );

    let out = std::path::Path::new("results/e2e_pretrain.csv");
    res.log.write_csv(out)?;
    res.log.write_eval_csv(std::path::Path::new("results/e2e_pretrain_eval.csv"))?;
    println!("wrote {} (+ _eval)", out.display());
    trainer.save_checkpoint(std::path::Path::new("results/e2e_checkpoint"))?;
    println!("checkpoint saved to results/e2e_checkpoint/");
    Ok(())
}
