//! RSS regression probe for the PJRT execute path.
//!
//! The `xla` crate's literal-based `execute` leaks every input device
//! buffer (its C wrapper `release()`s them and never frees — ~5 MB/step
//! at our artifact sizes, which OOMs a long run). Our runtime therefore
//! routes through `execute_b` with Rust-owned `PjRtBuffer`s; this probe
//! executes an artifact 60 times and prints RSS so the flat profile can
//! be re-verified after any runtime change (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo run --release --example leak_probe`

use lowrank_sge::runtime::Runtime;

fn rss_mb() -> u64 {
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).map(|x| x.parse::<u64>().unwrap_or(0)))
        .unwrap_or(0)
        * 4096
        / 1024
        / 1024
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::new(dir)?;
    let art = rt.load("clf_ipa_grad")?;
    let inputs = rt.golden_inputs(&art).unwrap_or_default();
    let start = rss_mb();
    println!("start RSS {start} MB");
    let mut last = start;
    for i in 0..60 {
        let _ = art.execute(&inputs)?;
        if i % 10 == 9 {
            last = rss_mb();
            println!("iter {i}: RSS {last} MB");
        }
    }
    let grown = last.saturating_sub(start);
    println!(
        "growth over 60 executes: {grown} MB — {}",
        if grown < 60 { "OK (no per-step leak)" } else { "LEAK suspected" }
    );
    Ok(())
}
