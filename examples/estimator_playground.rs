//! Estimator playground: sweep (r, c) on the toy problem and print the
//! closed-form MSE surface next to Monte-Carlo measurements — a compact
//! way to *see* Theorem 2, Remark 1 and the bias–variance trade-off.
//!
//! Run: `cargo run --release --example estimator_playground`

use lowrank_sge::estimator::mse::{one_shot_mse, EstimatorSpec, MseCurveConfig};
use lowrank_sge::estimator::theory;
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::estimator::Family;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;

fn main() {
    let problem = ToyProblem::paper_default(3);
    let w = problem.eval_point(4);
    let mut rng = Rng::new(5);
    let sxi = problem.sigma_xi_empirical(&w, &mut rng, 800, Family::Ipa, 1e-2);
    let sth = problem.sigma_theta(&w);
    let (txi, tth) = (sxi.trace(), sth.trace());
    println!("tr Σ_ξ = {txi:.3e}, tr Σ_Θ = {tth:.3e}, full-rank MSE_F = tr Σ_ξ");

    println!("\n-- Theorem 2 / Remark 1 surface (Stiefel law, closed form) --");
    println!("{:<6} {:<6} {:>14} {:>14}", "r", "c", "MSE(closed)", "MSE(measured)");
    for &r in &[2usize, 4, 8, 16] {
        for &c in &[0.1, r as f64 / problem.n as f64, 0.5, 1.0] {
            let closed = theory::mse_isotropic_exact(problem.n, r, c, txi, tth);
            let cfg = MseCurveConfig {
                family: Family::Ipa,
                spec: EstimatorSpec::LowRank(ProjectorKind::Stiefel),
                c,
                r,
                sample_sizes: vec![1],
                reps: 1,
                seed: 17,
                zo_sigma: 1e-2,
                warmup: 100,
            };
            let measured = one_shot_mse(&problem, &w, &cfg, 400);
            println!("{:<6} {:<6.3} {:>14.4e} {:>14.4e}", r, c, closed, measured);
        }
    }

    println!("\n-- the Gaussian penalty (Remark 1): MSE_G / MSE_Stiefel --");
    for &r in &[2usize, 4, 8, 16] {
        let g = theory::mse_gaussian_exact(problem.n, r, 1.0, txi, tth);
        let s = theory::mse_isotropic_exact(problem.n, r, 1.0, txi, tth);
        println!("r = {r:<3}: ratio {:.3} (→ 1 as r → n)", g / s);
    }

    println!("\n-- optimal c* minimizing the closed-form MSE --");
    for &r in &[2usize, 4, 8, 16] {
        let k0 = problem.n as f64 / r as f64;
        let c_star = tth / (k0 * (txi + tth));
        let at_cstar = theory::mse_isotropic_exact(problem.n, r, c_star, txi, tth);
        let at_one = theory::mse_isotropic_exact(problem.n, r, 1.0, txi, tth);
        println!(
            "r = {r:<3}: c* = {c_star:.4}, MSE(c*) = {at_cstar:.4e} vs MSE(1) = {at_one:.4e}"
        );
    }
}
