//! Quickstart: the paper's estimator in six steps.
//!
//! 1. build the toy problem (19) with its closed-form gradient;
//! 2. draw a Haar–Stiefel projector V (Algorithm 2);
//! 3. form the LowRank-IPA estimate ĝ·VVᵀ and check weak unbiasedness;
//! 4. compare the measured one-shot MSE of Gaussian vs Stiefel vs the
//!    instance-dependent optimum (Theorems 2–3 live);
//! 5. print the closed-form predictions next to the measurements;
//! 6. (if `make artifacts` has run) execute one real PJRT train step.
//!
//! Run: `cargo run --release --example quickstart`

use lowrank_sge::estimator::engine::project_lift;
use lowrank_sge::estimator::mse::{one_shot_mse, EstimatorSpec, MseCurveConfig};
use lowrank_sge::estimator::theory;
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::estimator::Family;
use lowrank_sge::linalg::Mat;
use lowrank_sge::projection::{ProjectionSampler, ProjectorKind, StiefelSampler};
use lowrank_sge::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // 1. the §6.1 toy problem at paper scale (m = n = 100, o = 30)
    let problem = ToyProblem::paper_default(1);
    let w = problem.eval_point(2);
    let g = problem.true_gradient(&w);
    println!("toy problem: ‖∇f(W)‖_F = {:.4}", g.fro_norm());

    // 2. a Haar–Stiefel projector (Algorithm 2): VᵀV = (cn/r)·I exactly
    let (r, c) = (4usize, 1.0);
    let mut sampler = StiefelSampler::new(problem.n, r, c);
    let v = sampler.sample(&mut rng);
    println!("Stiefel V: {}×{}, α = √(cn/r) = {:.3}", v.rows, v.cols, sampler.alpha());

    // 3. weak unbiasedness: average many projected IPA estimates → c·g
    let mut mean = Mat::zeros(problem.m, problem.n);
    let n_mc = 3000;
    for _ in 0..n_mc {
        let a = problem.sample_a(&mut rng);
        let ghat = problem.ipa_estimate(&w, &a);
        let v = sampler.sample(&mut rng);
        mean.axpy_inplace(1.0 / n_mc as f64, &project_lift(&ghat, &v));
    }
    let rel = mean.sub(&g.scaled(c)).fro_norm() / g.fro_norm();
    println!("E[ĝ·P] vs c·g: relative error {:.3} (Theorem 1)", rel);

    // 4–5. one-shot MSE: measured vs closed form for each projector law
    let mut rng2 = Rng::new(11);
    let sxi = problem.sigma_xi_empirical(&w, &mut rng2, 1000, Family::Ipa, 1e-2);
    let sth = problem.sigma_theta(&w);
    println!("\n{:<12} {:>12} {:>12}", "projector", "measured", "closed-form");
    let cases = [
        (ProjectorKind::Gaussian, theory::mse_gaussian_exact(problem.n, r, c, sxi.trace(), sth.trace())),
        (ProjectorKind::Stiefel, theory::mse_isotropic_exact(problem.n, r, c, sxi.trace(), sth.trace())),
        (ProjectorKind::Coordinate, theory::mse_isotropic_exact(problem.n, r, c, sxi.trace(), sth.trace())),
    ];
    for (kind, predicted) in cases {
        let cfg = MseCurveConfig {
            family: Family::Ipa,
            spec: EstimatorSpec::LowRank(kind),
            c,
            r,
            sample_sizes: vec![1],
            reps: 1,
            seed: 99,
            zo_sigma: 1e-2,
            warmup: 200,
        };
        let measured = one_shot_mse(&problem, &w, &cfg, 600);
        println!("{:<12} {:>12.4e} {:>12.4e}", kind.name(), measured, predicted);
    }

    // the instance-dependent optimum (Theorem 3)
    let cfg = MseCurveConfig {
        family: Family::Ipa,
        spec: EstimatorSpec::LowRank(ProjectorKind::Dependent),
        c,
        r,
        sample_sizes: vec![1],
        reps: 1,
        seed: 99,
        zo_sigma: 1e-2,
        warmup: 400,
    };
    let measured = one_shot_mse(&problem, &w, &cfg, 600);
    let mut rng3 = Rng::new(13);
    let sigma = problem.sigma_total(&w, &mut rng3, 1000, Family::Ipa, 1e-2);
    let spec = lowrank_sge::linalg::sym_eig(&sigma).values;
    let predicted = theory::mse_dependent_min(&spec, r, c, sth.trace());
    println!("{:<12} {:>12.4e} {:>12.4e}   ← Theorem 3 optimum", "dependent", measured, predicted);

    // 6. one real PJRT step, if the artifacts exist
    let dir = std::path::Path::new("artifacts");
    if dir.join("INDEX.txt").exists() {
        use lowrank_sge::coordinator::{PretrainConfig, PretrainTrainer};
        use lowrank_sge::runtime::Runtime;
        let mut rt = Runtime::new(dir)?;
        let mut cfg = PretrainConfig::quick("s", ProjectorKind::Stiefel);
        cfg.steps = 3;
        cfg.k_interval = 3;
        cfg.eval_every = 0;
        let mut trainer = PretrainTrainer::new(&mut rt, dir, cfg)?;
        let res = trainer.run()?;
        println!(
            "\nPJRT llama-s: 3 LowRank-IPA steps, loss {:.4} → {:.4}",
            res.log.records[0].loss,
            res.log.records[2].loss
        );
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` to see the PJRT step)");
    }
    Ok(())
}
